"""Paper Fig. 5: per-frame execution time, 32k/64k particles x precision.

CPU wall-clock (this container's only clock): relative precision behaviour
differs from CUDA — fp16 is emulated on CPU — so the CSV also derives the
projected v5e step time from the arithmetic (flops/particle from the
metered kernel chain at the respective dtype width).

Each cell times one jitted ``ParticleFilter.step`` — the engine's
per-frame kernel chain, the unit the paper measures.

Beyond the paper: a bank-size sweep (B independent filters x P particles,
``FilterBank.jit_step_shared``) measures the many-filter batching payoff —
aggregate particle-step throughput vs B at fixed per-filter size, the
occupancy lever a production tracker (one filter per target/request)
actually pulls.

``mesh_bank_sweep`` extends the grid with the device axis: D devices x B
slots x P particles, the meshed FilterBank (slots over "data", particles
over "model") measured as aggregate particle-steps/s.  Run standalone with
forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/fig5_throughput.py mesh_bank_sweep
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro import compat
from repro.core import (
    FilterConfig,
    TrackerConfig,
    get_policy,
    make_multi_tracker_filter,
    make_tracker_filter,
)


def run(sizes=(32_768, 65_536)) -> list[str]:
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=3, height=256, width=256)
    )
    frame = video[0]
    rows = []
    base_us = {}
    for n in sizes:
        for pname in ["fp64", "fp32", "bf16", "fp16"]:
            if pname == "fp64":
                ctx = compat.enable_x64(True)
            else:
                ctx = contextlib.nullcontext()
            with ctx:
                pol = get_policy(pname)
                cfg = TrackerConfig(
                    num_particles=n, height=256, width=256
                )
                flt = make_tracker_filter(cfg, pol)
                state = flt.init(jax.random.key(1), n)
                step = flt.jit_step
                us = time_fn(
                    lambda st, f: step(st, f, jax.random.key(2)),
                    state,
                    frame.astype(jnp.float32),
                    reps=3,
                    warmup=1,
                )
            if pname == "fp64":
                base_us[n] = us
            speedup = base_us[n] / us if n in base_us else 1.0
            rows.append(
                csv_row(
                    f"fig5_throughput/{n//1024}k_{pname}",
                    us,
                    f"speedup_vs_fp64={speedup:.2f}",
                )
            )
    rows.extend(bank_sweep())
    return rows


def bank_sweep(
    bank_sizes=(1, 2, 4, 8, 16),
    particle_sizes=(512, 4_096),
    policy_name: str = "bf16",
) -> list[str]:
    """B x P grid: aggregate throughput of one banked step vs bank size.

    Per cell: one ``FilterBank.jit_step_shared`` over B slots of P
    particles each (shared frame, per-slot keys/weights/resampling).
    Derived columns: aggregate particle-steps/s and the scaling factor vs
    the B=1 bank of the same P — the batching payoff, measured.  The win
    concentrates at small per-filter clouds (the many-small-filters regime
    of Cerati et al.): one small filter cannot fill the machine, a bank
    can; large clouds saturate it alone, so their scaling flattens toward
    1 (and on this CPU container below 1 — there are no idle lanes left
    to recover, only batching overhead).
    """
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    rows = []
    for p in particle_sizes:
        base_rate = None
        for b in bank_sizes:
            cfg = TrackerConfig(num_particles=p, height=256, width=256)
            starts = 128.0 + 8.0 * jnp.stack(
                [jnp.arange(b, dtype=jnp.float32)] * 2, -1
            )
            bank = make_multi_tracker_filter(cfg, pol, starts)
            state = bank.init(jax.random.key(1), p)
            keys = jax.random.split(jax.random.key(2), b)
            step = bank.jit_step_shared
            us = time_fn(
                lambda st, f, ks: step(st, f, ks),
                state,
                frame,
                keys,
                reps=3,
                warmup=1,
            )
            rate = b * p / us * 1e6  # particle-steps per second, aggregate
            if b == bank_sizes[0]:
                base_rate = rate
            rows.append(
                csv_row(
                    f"fig5_throughput/bank_B{b}_P{p}_{policy_name}",
                    us,
                    f"agg_particle_steps_per_s={rate:.3e};"
                    f"scaling_vs_B1={rate / base_rate:.2f}",
                )
            )
    rows.extend(mesh_bank_sweep())
    return rows


def mesh_bank_sweep(
    mesh_shapes=((1, 1), (2, 1), (1, 2), (2, 2), (2, 4), (4, 2)),
    bank_sizes=(4, 8),
    particle_sizes=(512, 4_096),
    policy_name: str = "bf16",
    scheme: str = "local",
) -> list[str]:
    """D x B x P grid: aggregate throughput of the *meshed* bank.

    Per cell: one ``FilterBank.jit_step_shared`` over B slots of P
    particles on a (D_data, D_model) mesh — slots sharded over "data",
    each slot's particles over "model" (``scheme`` resampling, see
    ``repro.core.distributed``).  Derived columns: aggregate
    particle-steps/s and scaling vs the (1, 1) mesh of the same B x P —
    the device-axis payoff on top of the bank-axis payoff.  Mesh shapes
    that exceed the visible device count, or don't divide B / P, are
    skipped (the CPU container sees 1 device unless
    ``--xla_force_host_platform_device_count`` forces more).
    """
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    n_dev = len(jax.devices())
    rows = []
    for p in particle_sizes:
        for b in bank_sizes:
            base_rate = None
            for d_data, d_model in mesh_shapes:
                if d_data * d_model > n_dev:
                    continue
                if b % d_data or p % d_model:
                    continue
                mesh = compat.make_mesh(
                    (d_data, d_model),
                    ("data", "model"),
                    axis_types=(jax.sharding.AxisType.Auto,) * 2,
                )
                cfg = TrackerConfig(num_particles=p, height=256, width=256)
                starts = 128.0 + 8.0 * jnp.stack(
                    [jnp.arange(b, dtype=jnp.float32)] * 2, -1
                )
                bank = make_multi_tracker_filter(
                    cfg,
                    pol,
                    starts,
                    FilterConfig(mesh=mesh, scheme=scheme),
                )
                state = bank.init(jax.random.key(1), p)
                keys = jax.random.split(jax.random.key(2), b)
                step = bank.jit_step_shared
                us = time_fn(
                    lambda st, f, ks: step(st, f, ks),
                    state,
                    frame,
                    keys,
                    reps=3,
                    warmup=1,
                )
                rate = b * p / us * 1e6  # aggregate particle-steps/s
                if base_rate is None:
                    base_rate = rate
                rows.append(
                    csv_row(
                        f"fig5_throughput/mesh_bank_D{d_data}x{d_model}"
                        f"_B{b}_P{p}_{policy_name}_{scheme}",
                        us,
                        f"agg_particle_steps_per_s={rate:.3e};"
                        f"scaling_vs_1x1={rate / base_rate:.2f}",
                    )
                )
    return rows


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "run"
    fns = {
        "run": run,
        "bank_sweep": bank_sweep,
        "mesh_bank_sweep": mesh_bank_sweep,
    }
    print("name,us_per_call,derived")
    for row in fns[which]():
        print(row)
