"""Paper Fig. 5: per-frame execution time, 32k/64k particles x precision.

CPU wall-clock (this container's only clock): relative precision behaviour
differs from CUDA — fp16 is emulated on CPU — so the CSV also derives the
projected v5e step time from the arithmetic (flops/particle from the
metered kernel chain at the respective dtype width).

Each cell times one jitted ``ParticleFilter.step`` — the engine's
per-frame kernel chain, the unit the paper measures.

Beyond the paper: a bank-size sweep (B independent filters x P particles,
``FilterBank.jit_step_shared``) measures the many-filter batching payoff —
aggregate particle-step throughput vs B at fixed per-filter size, the
occupancy lever a production tracker (one filter per target/request)
actually pulls.

``mesh_bank_sweep`` extends the grid with the device axis: D devices x B
slots x P particles, the meshed FilterBank (slots over "data", particles
over "model") measured as aggregate particle-steps/s.  Run standalone with
forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/fig5_throughput.py mesh_bank_sweep

``ragged_sweep`` measures the per-request particle-budget payoff: a
key-derived mix of request budgets served (a) padded to one max-width
dense bank vs (b) packed into per-size-class ragged banks — the useful
(budgeted) particle-steps/s gain of not paying max-P for easy requests.

``fused_sweep`` measures the fused weight epilogue (one-pass normalize +
ESS + CDF + resample kernel) against the composed kernel chain on the
isolated weight pipeline, per policy; ``fused_smoke`` is the CI gate
(fused must be no slower at the largest smoke size).

``elastic_sweep`` benches the ESS-driven budget controller
(``repro.core.elastic``) on a mixed-difficulty workload against static
padded-to-max, static blind size classes, and a difficulty oracle —
useful (difficulty-needed) particle-steps/s under measured per-width
costs; ``elastic_smoke`` is the CI gate (elastic must beat padded).

``packed_sweep`` benches the multi-bank packing engine's execution model
(``repro.launch.serve --packed``): one serve-style ladder workload run
(a) padded to one dense max-width bank, (b) as serve's pre-packing
single *ragged* bank — masked at lane width MAX, so compute still scales
with the widest request — and (c) as size-class packed banks, each class
at its own width.  Scored as useful particle-steps/s; a small real
``run_continuous_batching`` packed run stamps scheduler latency
percentiles and packing stats into BENCH_packed.json.  ``packed_smoke``
is the CI gate (packed must beat the single ragged bank).

Every sweep also emits a machine-readable ``BENCH_<sweep>.json``
(aggregate particle-steps/s per config) via
``benchmarks.common.write_bench_json``.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn, write_bench_json
from repro import compat
from repro.core import (
    FilterConfig,
    TrackerConfig,
    get_policy,
    make_multi_tracker_filter,
    make_tracker_filter,
)


def run(
    sizes=(32_768, 65_536),
    ragged=(8, 256, 2_048),
    fused_sizes=(8_192, 32_768),
    elastic=(8, 128, 2_048, 40),
    packed=(8, 256, 2_048),
) -> list[str]:
    """Paper grid + bank/mesh/ragged/fused/elastic/packed sweeps.
    ``ragged`` is the (num_requests, p_min, p_max) shape of the ragged
    sweep, ``fused_sizes`` the particle counts of the fused-epilogue
    sweep, ``elastic`` the (num_slots, p_min, p_max, ticks) shape of the
    elastic controller sweep, and ``packed`` the (num_slots, p_min,
    p_max) shape of the multi-bank packing sweep, so quick runs can
    shrink them alongside ``sizes``."""
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=3, height=256, width=256)
    )
    frame = video[0]
    rows = []
    records = []
    base_us = {}
    for n in sizes:
        for pname in ["fp64", "fp32", "bf16", "fp16"]:
            if pname == "fp64":
                ctx = compat.enable_x64(True)
            else:
                ctx = contextlib.nullcontext()
            with ctx:
                pol = get_policy(pname)
                cfg = TrackerConfig(
                    num_particles=n, height=256, width=256
                )
                flt = make_tracker_filter(cfg, pol)
                state = flt.init(jax.random.key(1), n)
                step = flt.jit_step
                us = time_fn(
                    lambda st, f: step(st, f, jax.random.key(2)),
                    state,
                    frame.astype(jnp.float32),
                    reps=3,
                    warmup=1,
                )
            if pname == "fp64":
                base_us[n] = us
            speedup = base_us[n] / us if n in base_us else 1.0
            rows.append(
                csv_row(
                    f"fig5_throughput/{n//1024}k_{pname}",
                    us,
                    f"speedup_vs_fp64={speedup:.2f}",
                )
            )
            records.append(
                {
                    "particles": n,
                    "policy": pname,
                    "us_per_step": us,
                    "particle_steps_per_s": n / us * 1e6,
                    "speedup_vs_fp64": speedup,
                }
            )
    write_bench_json("fig5", records)
    rows.extend(bank_sweep())
    rows.extend(
        ragged_sweep(num_requests=ragged[0], p_min=ragged[1], p_max=ragged[2])
    )
    rows.extend(fused_sweep(sizes=fused_sizes))
    rows.extend(
        elastic_sweep(
            num_slots=elastic[0],
            p_min=elastic[1],
            p_max=elastic[2],
            ticks=elastic[3],
        )
    )
    rows.extend(
        packed_sweep(num_slots=packed[0], p_min=packed[1], p_max=packed[2])
    )
    return rows


def bank_sweep(
    bank_sizes=(1, 2, 4, 8, 16),
    particle_sizes=(512, 4_096),
    policy_name: str = "bf16",
) -> list[str]:
    """B x P grid: aggregate throughput of one banked step vs bank size.

    Per cell: one ``FilterBank.jit_step_shared`` over B slots of P
    particles each (shared frame, per-slot keys/weights/resampling).
    Derived columns: aggregate particle-steps/s and the scaling factor vs
    the B=1 bank of the same P — the batching payoff, measured.  The win
    concentrates at small per-filter clouds (the many-small-filters regime
    of Cerati et al.): one small filter cannot fill the machine, a bank
    can; large clouds saturate it alone, so their scaling flattens toward
    1 (and on this CPU container below 1 — there are no idle lanes left
    to recover, only batching overhead).
    """
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    rows = []
    records = []
    for p in particle_sizes:
        base_rate = None
        for b in bank_sizes:
            cfg = TrackerConfig(num_particles=p, height=256, width=256)
            starts = 128.0 + 8.0 * jnp.stack(
                [jnp.arange(b, dtype=jnp.float32)] * 2, -1
            )
            bank = make_multi_tracker_filter(cfg, pol, starts)
            state = bank.init(jax.random.key(1), p)
            keys = jax.random.split(jax.random.key(2), b)
            step = bank.jit_step_shared
            us = time_fn(
                lambda st, f, ks: step(st, f, ks),
                state,
                frame,
                keys,
                reps=3,
                warmup=1,
            )
            rate = b * p / us * 1e6  # particle-steps per second, aggregate
            if b == bank_sizes[0]:
                base_rate = rate
            rows.append(
                csv_row(
                    f"fig5_throughput/bank_B{b}_P{p}_{policy_name}",
                    us,
                    f"agg_particle_steps_per_s={rate:.3e};"
                    f"scaling_vs_B1={rate / base_rate:.2f}",
                )
            )
            records.append(
                {
                    "bank": b,
                    "particles": p,
                    "policy": policy_name,
                    "us_per_step": us,
                    "agg_particle_steps_per_s": rate,
                    "scaling_vs_B1": rate / base_rate,
                }
            )
    write_bench_json("bank", records)
    rows.extend(mesh_bank_sweep())
    return rows


def mesh_bank_sweep(
    mesh_shapes=((1, 1), (2, 1), (1, 2), (2, 2), (2, 4), (4, 2)),
    bank_sizes=(4, 8),
    particle_sizes=(512, 4_096),
    policy_name: str = "bf16",
    scheme: str = "local",
) -> list[str]:
    """D x B x P grid: aggregate throughput of the *meshed* bank.

    Per cell: one ``FilterBank.jit_step_shared`` over B slots of P
    particles on a (D_data, D_model) mesh — slots sharded over "data",
    each slot's particles over "model" (``scheme`` resampling, see
    ``repro.core.distributed``).  Derived columns: aggregate
    particle-steps/s and scaling vs the (1, 1) mesh of the same B x P —
    the device-axis payoff on top of the bank-axis payoff.  Mesh shapes
    that exceed the visible device count, or don't divide B / P, are
    skipped (the CPU container sees 1 device unless
    ``--xla_force_host_platform_device_count`` forces more).
    """
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    n_dev = len(jax.devices())
    rows = []
    records = []
    for p in particle_sizes:
        for b in bank_sizes:
            base_rate = None
            for d_data, d_model in mesh_shapes:
                if d_data * d_model > n_dev:
                    continue
                if b % d_data or p % d_model:
                    continue
                mesh = compat.make_mesh(
                    (d_data, d_model),
                    ("data", "model"),
                    axis_types=(jax.sharding.AxisType.Auto,) * 2,
                )
                cfg = TrackerConfig(num_particles=p, height=256, width=256)
                starts = 128.0 + 8.0 * jnp.stack(
                    [jnp.arange(b, dtype=jnp.float32)] * 2, -1
                )
                bank = make_multi_tracker_filter(
                    cfg,
                    pol,
                    starts,
                    FilterConfig(mesh=mesh, scheme=scheme),
                )
                state = bank.init(jax.random.key(1), p)
                keys = jax.random.split(jax.random.key(2), b)
                step = bank.jit_step_shared
                us = time_fn(
                    lambda st, f, ks: step(st, f, ks),
                    state,
                    frame,
                    keys,
                    reps=3,
                    warmup=1,
                )
                rate = b * p / us * 1e6  # aggregate particle-steps/s
                if base_rate is None:
                    base_rate = rate
                rows.append(
                    csv_row(
                        f"fig5_throughput/mesh_bank_D{d_data}x{d_model}"
                        f"_B{b}_P{p}_{policy_name}_{scheme}",
                        us,
                        f"agg_particle_steps_per_s={rate:.3e};"
                        f"scaling_vs_1x1={rate / base_rate:.2f}",
                    )
                )
                records.append(
                    {
                        "mesh": [d_data, d_model],
                        "bank": b,
                        "particles": p,
                        "policy": policy_name,
                        "scheme": scheme,
                        "us_per_step": us,
                        "agg_particle_steps_per_s": rate,
                        "scaling_vs_1x1": rate / base_rate,
                    }
                )
    write_bench_json("mesh_bank", records)
    return rows


def ragged_sweep(
    num_requests: int = 8,
    p_min: int = 256,
    p_max: int = 2_048,
    policy_name: str = "bf16",
    seed: int = 0,
) -> list[str]:
    """Per-request particle budgets: pad-to-max vs size-class-packed ragged.

    Workload: ``num_requests`` tracker requests with key-derived particle
    budgets uniform in [p_min, p_max] (the heterogeneous-difficulty mix a
    ragged serving bank admits).  Two ways to run one frame for all of
    them:

    - **padded**: one dense bank at lane width p_max — every request pays
      the hardest request's cloud (the pre-ragged serving configuration);
    - **ragged**: requests grouped into power-of-two size classes, one
      *ragged* bank per class at the class width with ``n_active`` = the
      true budgets — each request pays its class, masking covers the
      within-class remainder.

    Throughput is *useful* (budgeted) particle-steps per second — the
    padded bank burns the same wall time on sum(p_max) lanes but only
    sum(budgets) of them were requested.  The summary row reports the
    ragged/padded useful-throughput gain; BENCH_ragged.json carries the
    full breakdown.
    """
    import numpy as np

    from repro.data.synthetic_video import VideoConfig, generate_video
    from repro.launch.serve import particle_size_classes

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    budgets = np.asarray(
        jax.random.randint(
            jax.random.key(seed), (num_requests,), p_min, p_max + 1
        )
    )
    classes = particle_size_classes(p_min, p_max)
    cls_of = [min(c for c in classes if c >= n) for n in budgets]
    useful = int(budgets.sum())
    rows, records = [], []

    def timed_bank(width, n_active, tag):
        b = len(n_active)
        cfg = TrackerConfig(num_particles=width, height=256, width=256)
        starts = 128.0 + 8.0 * jnp.stack(
            [jnp.arange(b, dtype=jnp.float32)] * 2, -1
        )
        bank = make_multi_tracker_filter(
            cfg, pol, starts,
            budgets=None if tag == "padded" else jnp.asarray(n_active),
        )
        state = bank.init(jax.random.key(1), width)
        keys = jax.random.split(jax.random.key(2), b)
        step = bank.jit_step_shared
        return time_fn(
            lambda st, f, ks: step(st, f, ks),
            state, frame, keys, reps=3, warmup=1,
        )

    # Padded baseline: every request at p_max, one dense bank.
    us_pad = timed_bank(p_max, [p_max] * num_requests, "padded")
    rate_pad = useful / us_pad * 1e6
    rows.append(
        csv_row(
            f"fig5_throughput/ragged_padded_B{num_requests}_P{p_max}"
            f"_{policy_name}",
            us_pad,
            f"useful_particle_steps_per_s={rate_pad:.3e};"
            f"lanes={num_requests * p_max}",
        )
    )
    records.append(
        {
            "config": "padded",
            "bank": num_requests,
            "width": p_max,
            "useful_particles": useful,
            "us_per_step": us_pad,
            "useful_particle_steps_per_s": rate_pad,
        }
    )

    # Ragged: one masked bank per size class at the class width.
    us_ragged = 0.0
    for c in classes:
        members = [int(n) for n, k in zip(budgets, cls_of) if k == c]
        if not members:
            continue
        us_c = timed_bank(c, members, "ragged")
        us_ragged += us_c
        rate_c = sum(members) / us_c * 1e6
        rows.append(
            csv_row(
                f"fig5_throughput/ragged_class{c}_B{len(members)}"
                f"_{policy_name}",
                us_c,
                f"useful_particle_steps_per_s={rate_c:.3e};"
                f"budgets={'+'.join(map(str, members))}",
            )
        )
        records.append(
            {
                "config": f"class_{c}",
                "bank": len(members),
                "width": c,
                "useful_particles": sum(members),
                "us_per_step": us_c,
                "useful_particle_steps_per_s": rate_c,
            }
        )
    rate_ragged = useful / us_ragged * 1e6
    gain = rate_ragged / rate_pad
    rows.append(
        csv_row(
            f"fig5_throughput/ragged_total_{policy_name}",
            us_ragged,
            f"useful_particle_steps_per_s={rate_ragged:.3e};"
            f"gain_vs_padded={gain:.2f}",
        )
    )
    records.append(
        {
            "config": "ragged_total",
            "useful_particles": useful,
            "us_per_step": us_ragged,
            "useful_particle_steps_per_s": rate_ragged,
            "gain_vs_padded": gain,
        }
    )
    write_bench_json(
        "ragged",
        records,
        p_min=p_min,
        p_max=p_max,
        budgets=[int(x) for x in budgets],
        gain_vs_padded=gain,
    )
    return rows


def elastic_sweep(
    num_slots: int = 8,
    p_min: int = 128,
    p_max: int = 2_048,
    ticks: int = 40,
    policy_name: str = "bf16",
    seed: int = 0,
    gate: bool = False,
) -> list[str]:
    """Elastic budgets vs static padded / static ragged / difficulty oracle.

    Workload: ``num_slots`` concurrent requests of *mixed difficulty* — a
    toy SMC model whose log-likelihood is ``scale * N(0, 1)`` per particle,
    so weights are lognormal and the per-step ESS of an n-particle slot is
    ~``n * exp(-scale^2)``: difficulty (scale) directly sets how many
    particles a slot needs for a target effective sample size.  Slot
    difficulties are spread so the *oracle* budgets cover the whole
    power-of-two ladder [p_min, p_max].

    Four allocation policies for the same workload:

    - **padded**:  every slot at p_max (the pre-ragged configuration);
    - **ragged_static**: admission-time size classes drawn blind (the
      key-derived draw serving uses when nothing measures difficulty);
    - **oracle**:  each slot at the smallest ladder class whose expected
      ESS meets the target — knows ``scale`` a priori;
    - **elastic**: starts from the same blind classes as ragged_static,
      then the real :class:`~repro.core.elastic.BudgetController` watches
      the real per-step ESS of a live ragged FilterBank and rewrites
      budgets via ``resize_slot`` (grow_below = p_min/2, shrink_above =
      2x that — the minimal deadband, which makes exactly one ladder
      class stable per slot: the oracle's).

    Scoring: *useful* particle-steps are ``min(budget, need)`` summed over
    slot-ticks (lanes beyond what the difficulty needs are waste; lanes
    below it are all useful but the slot under-delivers), and wall time
    charges each slot its ladder-class width using per-width slot costs
    measured on tracker banks (the packed-per-class execution model of
    ``ragged_sweep``; resize costs are excluded — they are O(events), not
    O(ticks)).  ``gate=True`` raises SystemExit unless elastic useful
    throughput >= static padded.  BENCH_elastic.json records the gains
    (acceptance: elastic >= 1.2x padded, >= 0.75x oracle) plus the full
    budget trajectories.
    """
    import numpy as np

    from repro.core import FilterBank
    from repro.core.elastic import BudgetController, ElasticConfig
    from repro.core.filter import SMCSpec
    from repro.data.synthetic_video import VideoConfig, generate_video
    from repro.launch.serve import particle_size_classes

    ladder = particle_size_classes(p_min, p_max)
    ess_target = p_min / 2.0  # grow floor E: serve's --elastic default
    # Per-slot difficulty, spread over the ladder: scale s_i chosen so the
    # expected ESS at the slot's intended class c_i sits mid-deadband
    # (sqrt(2) * E): exp(s^2) = c / (sqrt(2) * E).  With shrink_above =
    # 2E, the intended class is then the *unique* stable one — one class
    # down grows (ESS ~ 0.7E < E), one class up shrinks (ESS ~ 2.8E > 2E).
    intended = [ladder[i % len(ladder)] for i in range(num_slots)]
    scales = np.sqrt(
        # analysis: allow(host-log): workload-difficulty algebra on ESS
        # targets — not a particle-count log-weight constant
        np.log(np.asarray(intended) / (np.sqrt(2.0) * ess_target))
    )
    need = ess_target * np.exp(scales**2)  # particles for ESS == E
    oracle = np.asarray(
        [min([c for c in ladder if c >= n] or [p_max]) for n in need]
    )
    blind = np.asarray(ladder)[
        np.asarray(
            jax.random.randint(
                jax.random.key(seed), (num_slots,), 0, len(ladder)
            )
        )
    ]

    def toy_init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}

    def toy_transition(key, p, step):
        del step
        return {"x": jax.random.normal(key, p["x"].shape, jnp.float32)}

    def toy_loglik(p, obs, step):
        del step
        return obs * p["x"]

    bank = FilterBank(
        SMCSpec(toy_init, toy_transition, toy_loglik),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
        num_slots=num_slots,
    )
    ctrl = BudgetController(
        ElasticConfig(
            grow_below=ess_target,
            shrink_above=2.0 * ess_target,
            min_particles=p_min,
            max_particles=p_max,
            cooldown=2,
        ),
        num_slots,
    )
    state = bank.init(
        jax.random.key(seed + 1), p_max,
        n_active=jnp.asarray(blind, jnp.int32),
    )
    obs = jnp.asarray(scales, jnp.float32)
    budgets = blind.copy()
    busy = np.ones(num_slots, bool)
    traj, n_events = [], 0
    for t in range(ticks):
        keys = jax.random.split(
            jax.random.fold_in(jax.random.key(seed + 2), t), num_slots
        )
        state, out = bank.jit_step(state, obs, keys)
        for d in ctrl.observe(np.asarray(out.ess, np.float64), budgets, busy):
            if d.granted:
                n_events += 1
                state = bank.jit_resize_slot(
                    state,
                    jnp.int32(d.slot),
                    jax.random.fold_in(jax.random.key(seed + 3), n_events),
                    jnp.int32(d.new),
                )
                budgets[d.slot] = d.new
        traj.append(budgets.copy())

    # Measured per-slot step cost at each ladder width (tracker banks —
    # the packed-per-class execution model of ragged_sweep).
    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    cost_bank = 4
    us_slot = {}
    for w in ladder:
        cfg = TrackerConfig(num_particles=w, height=256, width=256)
        starts = 128.0 + 8.0 * jnp.stack(
            [jnp.arange(cost_bank, dtype=jnp.float32)] * 2, -1
        )
        tb = make_multi_tracker_filter(cfg, pol, starts)
        tstate = tb.init(jax.random.key(1), w)
        tkeys = jax.random.split(jax.random.key(2), cost_bank)
        step = tb.jit_step_shared
        us_slot[w] = time_fn(
            lambda st, f, ks: step(st, f, ks),
            tstate, frame, tkeys, reps=3, warmup=1,
        ) / cost_bank

    def score(budget_rows):
        """(useful particle-steps, wall us) over the slot-tick grid."""
        useful = sum(
            float(np.minimum(row, need).sum()) for row in budget_rows
        )
        wall = sum(
            sum(us_slot[int(b)] for b in row) for row in budget_rows
        )
        return useful, wall

    static = {
        "padded": [np.full(num_slots, p_max)] * ticks,
        "ragged_static": [blind] * ticks,
        "oracle": [oracle] * ticks,
    }
    rows, records, thpt = [], [], {}
    for name, budget_rows in {**static, "elastic": traj}.items():
        useful, wall = score(budget_rows)
        thpt[name] = useful / wall * 1e6
        rows.append(
            csv_row(
                f"fig5_throughput/elastic_{name}_B{num_slots}"
                f"_{p_min}_{p_max}",
                wall / ticks,
                f"useful_particle_steps_per_s={thpt[name]:.3e}",
            )
        )
        records.append(
            {
                "config": name,
                "slots": num_slots,
                "p_min": p_min,
                "p_max": p_max,
                "ticks": ticks,
                "useful_particles": useful,
                "wall_us": wall,
                "useful_particle_steps_per_s": thpt[name],
            }
        )
    gain_vs_padded = thpt["elastic"] / thpt["padded"]
    gain_vs_ragged = thpt["elastic"] / thpt["ragged_static"]
    vs_oracle = thpt["elastic"] / thpt["oracle"]
    rows.append(
        csv_row(
            f"fig5_throughput/elastic_gains_B{num_slots}",
            0.0,
            f"vs_padded={gain_vs_padded:.2f};"
            f"vs_ragged_static={gain_vs_ragged:.2f};"
            f"vs_oracle={vs_oracle:.2f}",
        )
    )
    write_bench_json(
        "elastic",
        records,
        ladder=[int(c) for c in ladder],
        ess_target=ess_target,
        scales=[round(float(s), 4) for s in scales],
        need=[round(float(n), 1) for n in need],
        oracle_budgets=[int(x) for x in oracle],
        blind_budgets=[int(x) for x in blind],
        final_budgets=[int(x) for x in traj[-1]],
        resize_events=n_events,
        controller_stats=ctrl.stats,
        gain_vs_padded=gain_vs_padded,
        gain_vs_ragged_static=gain_vs_ragged,
        vs_oracle=vs_oracle,
    )
    if gate and gain_vs_padded < 1.0:
        raise SystemExit(
            f"elastic useful throughput below static padded: "
            f"{gain_vs_padded:.2f} < 1.0 (see BENCH_elastic.json)"
        )
    return rows


def elastic_smoke() -> list[str]:
    """CI entry: reduced elastic sweep that *gates* on elastic >= padded
    useful particle-steps/s."""
    return elastic_sweep(
        num_slots=6, p_min=64, p_max=512, ticks=24, gate=True
    )


def packed_sweep(
    num_slots: int = 8,
    p_min: int = 256,
    p_max: int = 2_048,
    policy_name: str = "bf16",
    seed: int = 0,
    serve_steps: int = 8,
    gate: bool = False,
) -> list[str]:
    """Size-class packed banks vs the single ragged bank vs pad-to-max.

    Workload: ``num_slots`` concurrent requests with key-derived particle
    budgets from the power-of-two ladder [p_min, p_max] — the serving
    mix ``run_continuous_batching`` admits.  Three execution models for
    the same frame over all of them:

    - **padded**: one dense bank at lane width p_max (pre-ragged serving);
    - **single_ragged**: one *ragged* bank at lane width p_max with
      ``n_active`` = the true budgets — what ``serve --smc`` without
      ``--packed`` runs today.  Masking recovers resampling cost but the
      dense kernels still traverse p_max lanes per slot, so compute
      scales with the widest request in the bank;
    - **packed**: one bank per size class at the *class* width
      (``serve --packed`` / ``make_packed_banks``) — each request's
      kernels traverse only its class's lanes.

    Scored as useful (budgeted) particle-steps per second over the
    summed wall time.  A small real packed ``run_continuous_batching``
    run (toy SMC spec, async + pipelined uploads) then stamps scheduler
    latency percentiles, spillover, and occupancy into BENCH_packed.json
    — the end-to-end packing engine, not just the kernel model.
    ``gate=True`` raises SystemExit unless packed useful throughput >=
    the single ragged bank's.
    """
    import numpy as np

    from repro.core.filter import SMCSpec
    from repro.data.synthetic_video import VideoConfig, generate_video
    from repro.launch.serve import (
        make_packed_banks,
        particle_size_classes,
        run_continuous_batching,
    )

    ladder = particle_size_classes(p_min, p_max)
    budgets = np.asarray(ladder)[
        np.asarray(
            jax.random.randint(
                jax.random.key(seed), (num_slots,), 0, len(ladder)
            )
        )
    ]
    useful = int(budgets.sum())
    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=2, height=256, width=256)
    )
    frame = video[0].astype(jnp.float32)
    pol = get_policy(policy_name)
    rows, records = [], []

    def bank_timer(width, n_active, ragged):
        b = len(n_active)
        cfg = TrackerConfig(num_particles=width, height=256, width=256)
        starts = 128.0 + 8.0 * jnp.stack(
            [jnp.arange(b, dtype=jnp.float32)] * 2, -1
        )
        bank = make_multi_tracker_filter(
            cfg, pol, starts,
            budgets=jnp.asarray(n_active) if ragged else None,
        )
        state = bank.init(jax.random.key(1), width)
        keys = jax.random.split(jax.random.key(2), b)
        step = bank.jit_step_shared
        return lambda: time_fn(
            lambda st, f, ks: step(st, f, ks),
            state, frame, keys, reps=3, warmup=1,
        )

    # Timers built once, then timed in interleaved rounds with a
    # min-of-rounds reduction per config: a transient load spike hits one
    # round of every config instead of one whole config, so the
    # packed/single ratio the gate checks survives a noisy host.
    timers = {
        "padded": bank_timer(p_max, [p_max] * num_slots, ragged=False),
        "single_ragged": bank_timer(
            p_max, [int(b) for b in budgets], ragged=True
        ),
    }
    # Serve's packed lanes are ragged (spillover/migration need runtime
    # counts) — time them as such, at the class width.
    class_members = {
        c: [int(b) for b in budgets if b == c]
        for c in ladder
        if any(b == c for b in budgets)
    }
    class_timers = {
        c: bank_timer(c, members, ragged=True)
        for c, members in class_members.items()
    }
    us = {name: float("inf") for name in timers}
    us_class = {c: float("inf") for c in class_timers}
    for _ in range(3):
        for name, timer in timers.items():
            us[name] = min(us[name], timer())
        for c, timer in class_timers.items():
            us_class[c] = min(us_class[c], timer())

    us_packed = 0.0
    for c, members in class_members.items():
        us_c = us_class[c]
        us_packed += us_c
        rows.append(
            csv_row(
                f"fig5_throughput/packed_class{c}_B{len(members)}"
                f"_{policy_name}",
                us_c,
                f"useful_particle_steps_per_s="
                f"{sum(members) / us_c * 1e6:.3e}",
            )
        )
        records.append(
            {
                "config": f"class_{c}",
                "bank": len(members),
                "width": c,
                "useful_particles": sum(members),
                "us_per_step": us_c,
                "useful_particle_steps_per_s": sum(members) / us_c * 1e6,
            }
        )
    us["packed"] = us_packed
    thpt = {name: useful / u * 1e6 for name, u in us.items()}
    for name in ("padded", "single_ragged", "packed"):
        rows.append(
            csv_row(
                f"fig5_throughput/packed_{name}_B{num_slots}_P{p_max}"
                f"_{policy_name}",
                us[name],
                f"useful_particle_steps_per_s={thpt[name]:.3e}",
            )
        )
        records.append(
            {
                "config": name,
                "slots": num_slots,
                "p_min": p_min,
                "p_max": p_max,
                "useful_particles": useful,
                "us_per_step": us[name],
                "useful_particle_steps_per_s": thpt[name],
            }
        )
    gain_vs_padded = thpt["packed"] / thpt["padded"]
    gain_vs_single = thpt["packed"] / thpt["single_ragged"]
    rows.append(
        csv_row(
            f"fig5_throughput/packed_gains_B{num_slots}",
            0.0,
            f"vs_padded={gain_vs_padded:.2f};"
            f"vs_single_ragged={gain_vs_single:.2f}",
        )
    )

    # End-to-end scheduler pass: a real packed run_continuous_batching
    # run (toy SMC decode stand-in) for latency percentiles and packing
    # stats — cheap, and it exercises admission/spillover/retire rather
    # than just the kernel cost model.
    def toy_init(key, n):
        return {
            "x": jax.random.normal(key, (n,), jnp.float32),
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, serve_steps), jnp.int32),
        }

    def toy_transition(key, p, step):
        x = jax.random.normal(key, p["x"].shape, jnp.float32)
        tok = (jnp.abs(x) * 100).astype(jnp.int32)
        seq = jax.lax.dynamic_update_slice(
            p["seq"], tok[:, None], (jnp.int32(0), step.astype(jnp.int32))
        )
        return {"x": x, "cum_reward": p["cum_reward"] + x, "seq": seq}

    def toy_loglik(p, obs, step):
        del obs, step
        return p["x"]

    banks = make_packed_banks(
        SMCSpec(toy_init, toy_transition, toy_loglik),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
        num_slots=num_slots,
        p_min=p_min,
        p_max=p_max,
    )
    stats = run_continuous_batching(
        banks,
        num_requests=2 * num_slots,
        max_steps=serve_steps,
        particles=(p_min, p_max),
        key=jax.random.key(seed + 7),
        async_admit=True,
        pipelined_uploads=True,
    )
    lat = stats["latency"]
    pk = stats["packed"]
    rows.append(
        csv_row(
            f"fig5_throughput/packed_serve_B{num_slots}",
            lat["p50_ms"] * 1e3,
            f"p95_ms={lat['p95_ms']:.2f};"
            f"spillover={pk['spillover_admissions']};"
            f"occupancy={stats['occupancy']:.2f}",
        )
    )
    write_bench_json(
        "packed",
        records,
        ladder=[int(c) for c in ladder],
        budgets=[int(x) for x in budgets],
        gain_vs_padded=gain_vs_padded,
        gain_vs_single_ragged=gain_vs_single,
        serve_ticks=stats["ticks"],
        serve_occupancy=stats["occupancy"],
        serve_latency_p50_ms=lat["p50_ms"],
        serve_latency_p95_ms=lat["p95_ms"],
        serve_spillover_admissions=pk["spillover_admissions"],
        serve_classes={str(w): n for w, n in pk["classes"].items()},
    )
    if gate and gain_vs_single < 1.0:
        raise SystemExit(
            f"packed useful throughput below the single ragged bank: "
            f"{gain_vs_single:.2f} < 1.0 (see BENCH_packed.json)"
        )
    return rows


def packed_smoke() -> list[str]:
    """CI entry: reduced packed sweep that *gates* on packed >= the
    single-ragged bank's useful particle-steps/s."""
    return packed_sweep(num_slots=6, p_min=64, p_max=512, gate=True)


def fused_sweep(
    sizes=(8_192, 32_768),
    policies=("fp32", "bf16", "fp16"),
    bank: int = 8,
    reps: int = 7,
    gate: bool = False,
) -> list[str]:
    """Fused weight epilogue vs composed kernel chain, per policy x P.

    Per cell: the per-frame *weight pipeline* of a B-row bank in
    isolation -- exactly what the fusion optimizes (a full tracker step is
    likelihood-dominated, which would bury the epilogue delta in timer
    noise).  The composed variant runs the normalize kernel (with in-pass
    ESS sums) then the cumsum + search resampling chain; the fused variant
    runs the one-pass epilogue kernel (``repro.kernels.epilogue``).
    Outputs are bitwise-identical with the same keys (tests/test_epilogue),
    so the delta is pure execution cost: ~5 HBM traversals of the (B, P)
    weight array composed, vs two log-weight reads + one weights write +
    one ancestors write fused (CDF resident in VMEM).

    ``gate=True`` (the CI smoke) raises SystemExit if fused is slower than
    composed for *any* policy at the largest size.  BENCH_fused.json
    carries ``us_per_step`` for both variants plus the speedup column.
    """
    from repro.kernels.epilogue import ops as epi_ops
    from repro.kernels.logsumexp import ops as lse_ops
    from repro.kernels.resample import ops as res_ops

    @jax.jit
    def composed_step(keys, log_w):
        w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_batched(log_w)
        ess = jnp.square(sw) / sw2
        anc = res_ops.systematic_resample_batched(keys, w)
        return w, anc, lse, m, ess

    @jax.jit
    def fused_step(keys, log_w):
        w, anc, lse, m, sw, sw2 = epi_ops.fused_epilogue_batched(keys, log_w)
        return w, anc, lse, m, jnp.square(sw) / sw2

    rows, records = [], []
    gate_min = None
    for n in sizes:
        for pname in policies:
            pol = get_policy(pname)
            keys = jax.random.split(jax.random.key(0), bank)
            log_w = (
                jax.random.normal(jax.random.key(1), (bank, n), jnp.float32)
                * 30
            ).astype(pol.compute_dtype)
            us = {
                "composed": time_fn(
                    composed_step, keys, log_w, reps=reps, warmup=1
                ),
                "fused": time_fn(
                    fused_step, keys, log_w, reps=reps, warmup=1
                ),
            }
            speedup = us["composed"] / us["fused"]
            if n == max(sizes):
                gate_min = (
                    speedup if gate_min is None else min(gate_min, speedup)
                )
            rows.append(
                csv_row(
                    f"fig5_throughput/fused_B{bank}_{n//1024}k_{pname}",
                    us["fused"],
                    f"composed_us={us['composed']:.1f};"
                    f"speedup_fused_vs_composed={speedup:.2f}",
                )
            )
            records.append(
                {
                    "bank": bank,
                    "particles": n,
                    "policy": pname,
                    "us_per_step_fused": us["fused"],
                    "us_per_step_composed": us["composed"],
                    "particle_steps_per_s_fused": (
                        bank * n / us["fused"] * 1e6
                    ),
                    "speedup_fused_vs_composed": speedup,
                }
            )
    write_bench_json(
        "fused",
        records,
        largest_size=max(sizes),
        largest_size_min_speedup=gate_min,
    )
    if gate and gate_min is not None and gate_min < 1.0:
        raise SystemExit(
            f"fused epilogue slower than composed at P={max(sizes)}: "
            f"min speedup={gate_min:.2f} < 1.0 (see BENCH_fused.json)"
        )
    return rows


def fused_smoke() -> list[str]:
    """CI entry: quick fused sweep that *gates* on fused >= composed
    throughput (every policy) at the largest smoke size."""
    return fused_sweep(sizes=(8_192, 32_768), reps=7, gate=True)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "run"
    fns = {
        "run": run,
        "bank_sweep": bank_sweep,
        "mesh_bank_sweep": mesh_bank_sweep,
        "ragged_sweep": ragged_sweep,
        "fused_sweep": fused_sweep,
        "fused_smoke": fused_smoke,
        "elastic_sweep": elastic_sweep,
        "elastic_smoke": elastic_smoke,
        "packed_sweep": packed_sweep,
        "packed_smoke": packed_smoke,
    }
    print("name,us_per_call,derived")
    for row in fns[which]():
        print(row)
