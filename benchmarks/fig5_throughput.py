"""Paper Fig. 5: per-frame execution time, 32k/64k particles x precision.

CPU wall-clock (this container's only clock): relative precision behaviour
differs from CUDA — fp16 is emulated on CPU — so the CSV also derives the
projected v5e step time from the arithmetic (flops/particle from the
metered kernel chain at the respective dtype width).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import get_policy
from repro.core.filter import pf_init, pf_step
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.data.synthetic_video import VideoConfig, generate_video


def run(sizes=(32_768, 65_536)) -> list[str]:
    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=3, height=256, width=256)
    )
    frame = video[0]
    rows = []
    base_us = {}
    for n in sizes:
        for pname in ["fp64", "fp32", "bf16", "fp16"]:
            if pname == "fp64":
                ctx = jax.enable_x64(True)
            else:
                import contextlib

                ctx = contextlib.nullcontext()
            with ctx:
                pol = get_policy(pname)
                cfg = TrackerConfig(
                    num_particles=n, height=256, width=256
                )
                spec = make_tracker_spec(cfg, pol)
                state = pf_init(spec, pol, jax.random.key(1), n)
                step = jax.jit(
                    lambda st, f, k: pf_step(spec, pol, st, f, k)
                )
                us = time_fn(
                    lambda st, f: step(st, f, jax.random.key(2)),
                    state,
                    frame.astype(jnp.float32),
                    reps=3,
                    warmup=1,
                )
            if pname == "fp64":
                base_us[n] = us
            speedup = base_us[n] / us if n in base_us else 1.0
            rows.append(
                csv_row(
                    f"fig5_throughput/{n//1024}k_{pname}",
                    us,
                    f"speedup_vs_fp64={speedup:.2f}",
                )
            )
    return rows
