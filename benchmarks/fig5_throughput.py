"""Paper Fig. 5: per-frame execution time, 32k/64k particles x precision.

CPU wall-clock (this container's only clock): relative precision behaviour
differs from CUDA — fp16 is emulated on CPU — so the CSV also derives the
projected v5e step time from the arithmetic (flops/particle from the
metered kernel chain at the respective dtype width).

Each cell times one jitted ``ParticleFilter.step`` — the engine's
per-frame kernel chain, the unit the paper measures.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro import compat
from repro.core import TrackerConfig, get_policy, make_tracker_filter


def run(sizes=(32_768, 65_536)) -> list[str]:
    from repro.data.synthetic_video import VideoConfig, generate_video

    video, _ = generate_video(
        jax.random.key(0), VideoConfig(num_frames=3, height=256, width=256)
    )
    frame = video[0]
    rows = []
    base_us = {}
    for n in sizes:
        for pname in ["fp64", "fp32", "bf16", "fp16"]:
            if pname == "fp64":
                ctx = compat.enable_x64(True)
            else:
                ctx = contextlib.nullcontext()
            with ctx:
                pol = get_policy(pname)
                cfg = TrackerConfig(
                    num_particles=n, height=256, width=256
                )
                flt = make_tracker_filter(cfg, pol)
                state = flt.init(jax.random.key(1), n)
                step = flt.jit_step
                us = time_fn(
                    lambda st, f: step(st, f, jax.random.key(2)),
                    state,
                    frame.astype(jnp.float32),
                    reps=3,
                    warmup=1,
                )
            if pname == "fp64":
                base_us[n] = us
            speedup = base_us[n] / us if n in base_us else 1.0
            rows.append(
                csv_row(
                    f"fig5_throughput/{n//1024}k_{pname}",
                    us,
                    f"speedup_vs_fp64={speedup:.2f}",
                )
            )
    return rows
