"""Benchmark harness — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping (see DESIGN.md §9):

    fig4  accuracy per precision (tracking RMSE)
    fig5  runtime 32k/64k particles x precision
    fig6  normalizing+resampling kernel breakdown, naive vs fused
    fig7  pipeline utilization -> HLO op-mix, naive vs optimized
    fig8  threads-per-block -> Pallas BlockSpec sweep
    roofline  (arch x shape) terms from the dry-run artifacts, if present
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default="", help="comma list: fig4,fig5,fig6,fig7,fig8,roofline"
    )
    ap.add_argument("--quick", action="store_true", help="smaller sizes")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def enabled(name: str) -> bool:
        return want is None or name in want

    print("name,us_per_call,derived")
    failures = 0

    if enabled("fig4"):
        from benchmarks import fig4_accuracy

        kw = dict(frames=30, size=128, particles=512) if args.quick else {}
        failures += _emit(lambda: fig4_accuracy.run(**kw))
    if enabled("fig5"):
        from benchmarks import fig5_throughput

        kw = (
            dict(
                sizes=(8192,),
                ragged=(4, 128, 512),
                fused_sizes=(8192,),
                elastic=(4, 64, 512, 16),
                packed=(4, 64, 512),
            )
            if args.quick
            else {}
        )
        failures += _emit(lambda: fig5_throughput.run(**kw))
    if enabled("fig6"):
        from benchmarks import fig6_kernels

        failures += _emit(fig6_kernels.run)
    if enabled("fig7"):
        from benchmarks import fig7_opmix

        failures += _emit(fig7_opmix.run)
    if enabled("fig8"):
        from benchmarks import fig8_blocksweep

        kw = dict(n=16_384) if args.quick else {}
        failures += _emit(lambda: fig8_blocksweep.run(**kw))
    if enabled("roofline"):
        failures += _emit(_roofline_rows)

    if failures:
        sys.exit(1)


def _emit(fn) -> int:
    try:
        for row in fn():
            print(row)
        return 0
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        return 1


def _roofline_rows():
    """Summarize roofline artifacts as CSV (no-op if dry-run not yet run)."""
    from repro.launch.roofline import build_table

    rows = []
    for r in build_table():
        if r["status"] != "ok":
            continue
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            f"roofline/{r['arch']}/{r['shape']},{dom_s * 1e6:.1f},"
            f"dominant={r['dominant']};frac={r['roofline_frac']:.3f}"
        )
    return rows


if __name__ == "__main__":
    main()
