"""Paper Fig. 7: pipeline utilization -> HLO op-mix counts.

No Nsight on CPU: the structural stand-in is the lowered-HLO operation mix.
The paper's XU-pipeline overload shows up as convert/divide/rsqrt ops in
the naive chain; the optimized chain hoists reciprocals (one precomputed
constant) and works in log space — the counts drop accordingly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.likelihood import IntensityModel
from repro.core.precision import get_policy
from repro.launch.hlo import op_mix


def _naive_step(patches, log_w_prev):
    """Paper's naive fp16 port: Eq. 3 with in-loop divides and int->float
    converts, direct exp weighting."""
    model = IntensityModel()
    idx = jnp.arange(patches.shape[-1], dtype=jnp.int32)
    scale = (50.0 * patches.shape[-1]).__float__()
    # int->float converts + per-element divides (the XU traffic)
    weights_pos = idx.astype(patches.dtype) * 0 + 1
    db = patches - jnp.asarray(model.background, patches.dtype)
    df = patches - jnp.asarray(model.foreground, patches.dtype)
    ll = jnp.sum((db * db - df * df) * weights_pos / scale, axis=-1)
    w = jnp.exp(log_w_prev + ll)  # direct exp
    return w / jnp.sum(w)


def _optimized_step(patches, log_w_prev):
    """Stable scaled-square + LSE with hoisted constants."""
    from repro.core.likelihood import intensity_loglik

    model = IntensityModel()
    pol = get_policy("fp16")
    ll = intensity_loglik(patches, model, pol)
    from repro.core.stability import normalize_log_weights

    w, _ = normalize_log_weights(log_w_prev + ll)
    return w


def run(p: int = 4096, j: int = 69) -> list[str]:
    patches = jax.random.uniform(
        jax.random.key(0), (p, j), jnp.float32, 60, 250
    ).astype(jnp.float16)
    log_w = jnp.zeros((p,), jnp.float16)
    rows = []
    for name, fn in [("naive", _naive_step), ("optimized", _optimized_step)]:
        hlo = jax.jit(fn).lower(patches, log_w).compile().as_text()
        mix = op_mix(hlo)
        derived = ";".join(
            f"{k}={mix[k]}"
            for k in ("convert", "divide", "exponential", "rsqrt", "reduce")
        )
        rows.append(csv_row(f"fig7_opmix/{name}", 0.0, derived))
    return rows
