"""Paper Fig. 4: tracking accuracy per precision vs ground truth.

Every precision drives the same ``ParticleFilter`` engine; only the
``FilterConfig.policy`` name changes (the paper's
``particleFilter<double/float/half>`` axis as a registry lookup).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro import compat
from repro.core import TrackerConfig, get_policy, make_tracker_filter
from repro.data.synthetic_video import VideoConfig, generate_video


def _time_tracker(pol, video, frames, size, particles):
    cfg = TrackerConfig(num_particles=particles, height=size, width=size)
    flt = make_tracker_filter(cfg, pol)
    t0 = time.perf_counter()
    _, outs = jax.jit(lambda k, v: flt.run(k, v, particles))(
        jax.random.key(1), video
    )
    traj = outs.estimate["pos"]
    jax.block_until_ready(traj)
    us = (time.perf_counter() - t0) / frames * 1e6
    return traj, us


def run(frames: int = 60, size: int = 256, particles: int = 1024) -> list[str]:
    video, truth = generate_video(
        jax.random.key(0),
        VideoConfig(num_frames=frames, height=size, width=size),
    )
    rows = []
    for pname in ["fp32", "bf16", "fp16", "bf16_mixed", "fp16_naive"]:
        traj, us = _time_tracker(
            get_policy(pname), video, frames, size, particles
        )
        t = np.asarray(traj, np.float64)
        rmse = float(np.sqrt(np.mean(np.sum((t - np.asarray(truth)) ** 2, -1))))
        rows.append(
            csv_row(f"fig4_accuracy/{pname}", us, f"rmse_px={rmse:.3f}")
        )
    with compat.enable_x64(True):
        video64, truth64 = generate_video(
            jax.random.key(0),
            VideoConfig(num_frames=frames, height=size, width=size),
        )
        traj, us = _time_tracker(
            get_policy("fp64"), video64, frames, size, particles
        )
        rmse = float(
            np.sqrt(np.mean(np.sum((np.asarray(traj) - np.asarray(truth64)) ** 2, -1)))
        )
        rows.append(csv_row("fig4_accuracy/fp64", us, f"rmse_px={rmse:.3f}"))
    return rows
