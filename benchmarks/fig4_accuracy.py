"""Paper Fig. 4: tracking accuracy per precision vs ground truth."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import TrackerConfig, get_policy, track
from repro.data.synthetic_video import VideoConfig, generate_video


def run(frames: int = 60, size: int = 256, particles: int = 1024) -> list[str]:
    video, truth = generate_video(
        jax.random.key(0),
        VideoConfig(num_frames=frames, height=size, width=size),
    )
    rows = []
    for pname in ["fp32", "bf16", "fp16", "bf16_mixed", "fp16_naive"]:
        pol = get_policy(pname)
        cfg = TrackerConfig(num_particles=particles, height=size, width=size)
        t0 = time.perf_counter()
        traj, outs = jax.jit(lambda k, v: track(k, v, cfg, pol))(
            jax.random.key(1), video
        )
        jax.block_until_ready(traj)
        us = (time.perf_counter() - t0) / frames * 1e6
        t = np.asarray(traj, np.float64)
        rmse = float(np.sqrt(np.mean(np.sum((t - np.asarray(truth)) ** 2, -1))))
        rows.append(
            csv_row(f"fig4_accuracy/{pname}", us, f"rmse_px={rmse:.3f}")
        )
    with jax.enable_x64(True):
        video64, truth64 = generate_video(
            jax.random.key(0),
            VideoConfig(num_frames=frames, height=size, width=size),
        )
        pol = get_policy("fp64")
        cfg = TrackerConfig(num_particles=particles, height=size, width=size)
        t0 = time.perf_counter()
        traj, _ = jax.jit(lambda k, v: track(k, v, cfg, pol))(
            jax.random.key(1), video64
        )
        jax.block_until_ready(traj)
        us = (time.perf_counter() - t0) / frames * 1e6
        rmse = float(
            np.sqrt(np.mean(np.sum((np.asarray(traj) - np.asarray(truth64)) ** 2, -1)))
        )
        rows.append(csv_row("fig4_accuracy/fp64", us, f"rmse_px={rmse:.3f}"))
    return rows
