"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "csv_row"]


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
