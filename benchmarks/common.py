"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import subprocess
import time

import jax

__all__ = ["git_sha", "time_fn", "csv_row", "write_bench_json"]


def git_sha() -> str | None:
    """The repo HEAD commit, or None outside a git checkout — stamped into
    every BENCH_*.json so successive runs form a comparable trajectory."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def _analysis_finding_count():
    """New (non-baselined) precision-contract lint findings at bench time,
    stamped into every BENCH_*.json — a nonzero count flags numbers
    measured on a tree that violates the audited numerics contracts.
    None when the analyzer can't run (e.g. a vendored benchmarks/ copy)."""
    try:
        from repro.analysis import load_baseline, run_lint, split_baseline

        new, _ = split_baseline(run_lint(), load_baseline())
        return len(new)
    except Exception:
        return None


def _health_counters():
    """Process-wide health trips/recoveries accumulated by the run that
    produced this bench (``repro.core.health``), stamped into every
    BENCH_*.json — a throughput number measured while slots were tripping
    sentinels and replaying recovery surgery should say so.  None when
    the health layer isn't importable (e.g. a vendored benchmarks/)."""
    try:
        from repro.core.health import health_counters

        return health_counters()
    except Exception:
        return None


def write_bench_json(name: str, records: list[dict], **meta) -> str:
    """Write a machine-readable ``BENCH_<name>.json`` next to the cwd.

    ``records`` is a list of flat dicts (one per swept config — aggregate
    particle-steps/s and friends); ``meta`` adds sweep-level fields
    (device count, derived summary numbers).  CI and downstream tooling
    parse these instead of scraping the CSV stdout.
    """
    payload = {
        "bench": name,
        "git_sha": git_sha(),
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "analysis_findings": _analysis_finding_count(),
        "health": _health_counters(),
        **meta,
        "records": records,
    }
    path = os.path.join(os.getcwd(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
