"""Chaos benchmark: fault-class recovery rate + recovery latency.

Runs ``repro.launch.serve.run_continuous_batching`` under the
deterministic fault injector (``repro.core.faults``) with the health
monitor + escalation ladder enabled, then *joins* the injector's ground
truth (which fault, which tick, which slot — ``stats["chaos"]["log"]``)
against the monitor's recovery records (``stats["health"]["recovered"]``)
to score each fault class:

- **containment rate** — injected faults whose incident was closed
  (recovered *or* retired-with-error; either way no junk tokens escaped
  and the serve loop survived).  Step-level faults (``fail_step`` /
  ``delay_step``) are absorbed at dispatch, so they score against the
  retry / watchdog counters instead of incident records.
- **recovery latency** — ticks from the fault's applied tick to the
  incident's close, per class (state faults only; step faults are
  absorbed on their own tick).

Two scenarios per sweep — the synchronous single ragged bank and the
packed + async + fp32-fallback configuration — because the detection lag
and the escalation pacing differ between them (async observes one tick
late).  ``chaos_smoke`` is the CI gate: every applied fault class must be
fully contained, at least one recovery must have happened, and the
**no-fault identity check** must pass — serving with monitoring enabled
but zero faults injected must produce bitwise the same tokens as serving
with no health layer at all (detection is free *and* inert until
something actually breaks).

The workload is a toy SMC spec whose log-likelihood reads *carried*
particle state (an AR(1) chain), so NaN-poisoned particle rows propagate
into the weight pipeline exactly as a real decode cache blow-up would —
a spec that re-derives its reward from the step key each tick would
silently shrug the poison off and score a fake recovery.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, write_bench_json

_STATE_CLASSES = ("nan_lanes", "inf_weights", "drop_upload")


def _chaos_spec(steps: int):
    """Toy decode-shaped SMC spec with *carried* state in the likelihood.

    ``x`` follows an AR(1) chain and the reward is ``-x^2``, so a
    NaN/Inf poisoned slot stays poisoned through transitions until a
    ladder rung (rollback / reseed / migration) actually replaces the
    state — the property the chaos score depends on.  ``cum_reward`` /
    ``seq`` match the decode contract ``run_continuous_batching``
    retires against.
    """
    from repro.core.filter import SMCSpec

    def init(key, n):
        return {
            "x": jax.random.normal(key, (n,), jnp.float32),
            "reward": jnp.zeros((n,), jnp.float32),
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        noise = jax.random.normal(key, p["x"].shape, jnp.float32)
        x = 0.9 * p["x"] + 0.1 * noise
        reward = -jnp.square(x)
        tok = (jnp.abs(x) * 97.0).astype(jnp.int32) % 1000
        pos = jnp.minimum(step.astype(jnp.int32), steps - 1)
        return {
            "x": x,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    return SMCSpec(init, transition, loglik)


def _build_bank(num_slots, p_max, max_steps, *, packed=False, p_min=None):
    from repro.core import FilterBank, FilterConfig, get_policy
    from repro.launch.serve import make_packed_banks

    spec = _chaos_spec(max_steps)
    cfg = FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0)
    if packed:
        return make_packed_banks(
            spec, cfg, num_slots=num_slots, p_min=p_min, p_max=p_max
        )
    return FilterBank(spec, cfg, num_slots=num_slots)


def _run_scenario(
    name: str,
    *,
    num_slots: int,
    num_requests: int,
    max_steps: int,
    p_min: int,
    p_max: int,
    seed: int,
    chaos,
    health,
    packed: bool = False,
    async_admit: bool = False,
    fallback_slots: int = 0,
) -> dict:
    from repro.core import FilterBank, FilterConfig, get_policy
    from repro.launch.serve import run_continuous_batching

    bank = _build_bank(
        num_slots, p_max, max_steps, packed=packed, p_min=p_min
    )
    fallback = None
    if fallback_slots:
        fallback = FilterBank(
            _chaos_spec(max_steps),
            FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
            num_slots=fallback_slots,
        )
    stats = run_continuous_batching(
        bank,
        num_requests=num_requests,
        max_steps=max_steps,
        particles=(p_min, p_max),
        key=jax.random.key(seed),
        async_admit=async_admit,
        health=health,
        chaos=chaos,
        fallback_bank=fallback,
    )
    stats["scenario"] = name
    return stats


def _score(stats: dict, detect_window: int = 4) -> list[dict]:
    """Join injected faults against recovery records, per fault class.

    A state fault is contained when a recovery record *covers* it: the
    incident tripped within ``detect_window`` ticks of the fault landing
    (detection lag is 1 tick sync, 2 async) and closed at/after it.
    Coverage is not one-to-one — a second fault poisoning a slot whose
    incident is still open merges into that incident (the monitor counts
    incidents, not unhealthy ticks), so one record may contain several
    faults.  Slot-matched records are preferred; a slot-free fallback
    catches incidents that migrated to the fp32 fallback bank before
    closing.  Step faults (``fail_step``/``delay_step``) score against
    the retry / watchdog counters: containment there means the
    dispatch-level backoff absorbed them.
    """
    chaos = stats["chaos"]
    health = stats["health"]
    recovered = health["recovered"]

    def match(fault):
        for require_slot in (True, False):
            best = None
            for rec in recovered:
                if require_slot and rec["slot"] != fault["slot"]:
                    continue
                if rec["recovered_tick"] < fault["tick"]:
                    continue
                if rec["trip_tick"] > fault["tick"] + detect_window:
                    continue
                if (
                    best is None
                    or rec["recovered_tick"] < best["recovered_tick"]
                ):
                    best = rec
            if best is not None:
                return best
        return None

    by_class: dict[str, dict] = {}
    for fault in chaos["log"]:
        cls = by_class.setdefault(
            fault["kind"],
            {"applied": 0, "contained": 0, "latencies": [], "actions": []},
        )
        cls["applied"] += 1
        if fault["kind"] in _STATE_CLASSES:
            rec = match(fault)
            if rec is not None:
                cls["contained"] += 1
                cls["latencies"].append(
                    rec["recovered_tick"] - fault["tick"]
                )
                cls["actions"].append(rec["action"])
    for kind, counter in (
        ("fail_step", health["step_retries"]),
        ("delay_step", health["watchdog_trips"]),
    ):
        cls = by_class.get(kind)
        if cls is not None:
            cls["contained"] = min(cls["applied"], int(counter))

    records = []
    for kind, cls in sorted(by_class.items()):
        lat = cls["latencies"]
        records.append(
            {
                "scenario": stats["scenario"],
                "fault": kind,
                "applied": cls["applied"],
                "contained": cls["contained"],
                "containment_rate": (
                    cls["contained"] / cls["applied"] if cls["applied"] else 1.0
                ),
                "mean_recovery_latency_ticks": (
                    sum(lat) / len(lat) if lat else 0.0
                ),
                "max_recovery_latency_ticks": max(lat) if lat else 0,
                "actions": sorted(set(cls["actions"])),
            }
        )
    return records


def identity_check(
    num_slots: int = 4,
    num_requests: int = 6,
    max_steps: int = 8,
    p_min: int = 8,
    p_max: int = 32,
    seed: int = 0,
) -> None:
    """No-fault bitwise identity: health monitoring on (zero faults)
    vs no health layer at all must produce identical tokens, tick for
    tick.  Raises SystemExit on any divergence."""
    import numpy as np

    from repro.core import HealthConfig
    from repro.launch.serve import run_continuous_batching

    runs = []
    for health in (None, HealthConfig()):
        bank = _build_bank(num_slots, p_max, max_steps)
        runs.append(
            run_continuous_batching(
                bank,
                num_requests=num_requests,
                max_steps=max_steps,
                particles=(p_min, p_max),
                key=jax.random.key(seed),
                health=health,
            )
        )
    plain, monitored = runs
    if plain["ticks"] != monitored["ticks"]:
        raise SystemExit(
            f"identity check: tick counts diverged "
            f"({plain['ticks']} vs {monitored['ticks']})"
        )
    for a, b in zip(plain["results"], monitored["results"]):
        if a["id"] != b["id"] or a["steps"] != b["steps"]:
            raise SystemExit(
                f"identity check: request bookkeeping diverged on "
                f"req[{a['id']}] vs req[{b['id']}]"
            )
        if not np.array_equal(a["tokens"], b["tokens"]):
            raise SystemExit(
                f"identity check: tokens diverged on req[{a['id']}]"
            )
    hm = monitored["health"]
    if hm["trips"] or hm["open_incidents"]:
        raise SystemExit(
            f"identity check: spurious health trips on a fault-free run: "
            f"{hm['trips']} open={hm['open_incidents']}"
        )


def chaos_sweep(
    num_slots: int = 4,
    num_requests: int = 10,
    max_steps: int = 10,
    p_min: int = 8,
    p_max: int = 32,
    seed: int = 0,
    rounds: int = 2,
    gate: bool = False,
) -> list[str]:
    """Both scenarios x all fault classes -> BENCH_chaos.json.

    ``gate=True`` (the CI smoke) raises SystemExit when any applied
    fault class is not fully contained, when no recovery happened at
    all, or when the no-fault identity check fails.
    """
    from repro.core import ChaosConfig, HealthConfig
    from repro.core.faults import FAULT_CLASSES

    chaos = ChaosConfig(
        classes=FAULT_CLASSES,
        rounds=rounds,
        start_tick=2,
        every=2,
        fail_attempts=1,
        delay_ms=30.0,
    )
    health = HealthConfig(step_timeout_ms=20.0, snapshot_every=3)
    scenarios = [
        dict(name="sync_ragged"),
        dict(
            name="packed_async_fallback",
            packed=True,
            async_admit=True,
            fallback_slots=1,
        ),
    ]
    rows, records, summaries = [], [], []
    for sc in scenarios:
        stats = _run_scenario(
            sc.pop("name"),
            num_slots=num_slots,
            num_requests=num_requests,
            max_steps=max_steps,
            p_min=p_min,
            p_max=p_max,
            seed=seed,
            chaos=chaos,
            health=health,
            **sc,
        )
        scored = _score(stats)
        records.extend(scored)
        health_s = stats["health"]
        summaries.append(
            {
                "scenario": stats["scenario"],
                "ticks": stats["ticks"],
                "injected": stats["chaos"]["applied"],
                "scheduled": stats["chaos"]["scheduled"],
                "trips": health_s["trips"],
                "recoveries": health_s["recoveries"],
                "retired_error": health_s["retired_error"],
                "open_incidents": len(health_s["open_incidents"]),
                "errored_requests": sorted(
                    r["id"] for r in stats["results"] if "error" in r
                ),
            }
        )
        for rec in scored:
            rows.append(
                csv_row(
                    f"chaos/{rec['scenario']}_{rec['fault']}",
                    0.0,
                    f"applied={rec['applied']};"
                    f"contained={rec['contained']};"
                    f"rate={rec['containment_rate']:.2f};"
                    f"mean_latency_ticks="
                    f"{rec['mean_recovery_latency_ticks']:.1f}",
                )
            )
    identity_check(
        num_slots=num_slots,
        max_steps=max_steps,
        p_min=p_min,
        p_max=p_max,
        seed=seed,
    )
    rows.append(csv_row("chaos/no_fault_identity", 0.0, "bitwise=ok"))
    write_bench_json(
        "chaos",
        records,
        scenarios=summaries,
        fault_rounds=rounds,
        no_fault_identity="ok",
    )
    if gate:
        uncontained = [
            f"{r['scenario']}/{r['fault']}={r['containment_rate']:.2f}"
            for r in records
            if r["applied"] and r["containment_rate"] < 1.0
        ]
        if uncontained:
            raise SystemExit(
                f"chaos gate: uncontained fault classes: "
                f"{', '.join(uncontained)} (see BENCH_chaos.json)"
            )
        if not any(s["recoveries"] for s in summaries):
            raise SystemExit(
                "chaos gate: no recoveries recorded — the harness "
                "injected faults but the ladder never acted"
            )
    return rows


def chaos_smoke() -> list[str]:
    """CI entry: reduced chaos sweep that *gates* on full containment of
    every applied fault class + the no-fault bitwise identity check."""
    return chaos_sweep(rounds=1, gate=True)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "chaos_sweep"
    fns = {
        "chaos_sweep": chaos_sweep,
        "chaos_smoke": chaos_smoke,
        "identity_check": lambda: (identity_check(), [])[1],
    }
    print("name,us_per_call,derived")
    for row in fns[which]():
        print(row)
