"""Paper Fig. 6: normalizing + resampling kernel breakdown, naive vs fused.

naive   = the paper's pre-optimization chain: separate max-find, weighting
          (exp), sum, divide, then CDF build + search, each its own jit
          (kernel-launch analogue).
fused   = the optimized chain: one fused LSE-normalize + one fused
          cumsum+search call (the Pallas kernels; timed via their jnp oracle
          semantics under one jit so CPU timing reflects the fusion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.precision import get_policy
from repro.kernels.logsumexp import ops as lse_ops
from repro.kernels.resample import ops as res_ops


def _naive_chain(log_w, key):
    # separate "kernels", mirroring the paper's max/weight/normalize split
    m = jax.jit(jnp.max)(log_w)
    w = jax.jit(jnp.exp)(log_w - m)
    s = jax.jit(jnp.sum)(w)
    wn = jax.jit(jnp.divide)(w, s)
    cdf = jax.jit(jnp.cumsum)(wn)
    u0 = jax.random.uniform(key, (), jnp.float32)
    n = log_w.shape[0]
    u = (jnp.arange(n, dtype=jnp.float32) + u0) / n
    anc = jax.jit(jnp.searchsorted, static_argnames="side")(cdf, u, side="right")
    return anc


@jax.jit
def _fused_chain(log_w, key):
    w, m, lse = lse_ops.normalize_weights(log_w)
    return res_ops.systematic_resample(key, w)


def run(n: int = 8192) -> list[str]:
    rows = []
    for pname in ["fp32", "fp16", "bf16"]:
        pol = get_policy(pname)
        log_w = (
            jax.random.normal(jax.random.key(0), (n,), jnp.float32) * 20
        ).astype(pol.compute_dtype)
        key = jax.random.key(1)
        us_naive = time_fn(_naive_chain, log_w, key, reps=5)
        us_fused = time_fn(_fused_chain, log_w, key, reps=5)
        rows.append(
            csv_row(
                f"fig6_kernels/naive_{pname}", us_naive,
                f"n={n};kernels=7",
            )
        )
        rows.append(
            csv_row(
                f"fig6_kernels/fused_{pname}", us_fused,
                f"n={n};kernels=2;speedup={us_naive/us_fused:.2f}",
            )
        )
    return rows
