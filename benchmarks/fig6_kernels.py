"""Paper Fig. 6: per-kernel breakdown — naive vs fused, chain vs one pass.

Two sweeps:

``run``        the paper's normalizing + resampling kernel breakdown:
               naive = separate max/exp/sum/divide/cumsum/search jits
               (kernel-launch analogue), fused = the one-pass LSE-normalize
               + cumsum-search Pallas chain.

``step_sweep`` the full-step fusion on top of that: the composed
               likelihood kernel → weight add → fused-epilogue chain
               (the engine's best pre-fusion path) vs the single
               streaming fused-step kernel (``repro.kernels.step``) that
               scores patches, folds the uniform prior, and runs the
               whole weight epilogue without materializing the (B, P)
               log-weight array.  Outputs are bitwise-identical with the
               same keys (tests/test_step.py), so the delta is pure
               execution cost.  Emits ``BENCH_fig6.json``
               (``us_per_step`` both variants + speedup per record);
               ``step_smoke`` is the CI gate — fused must be no slower
               than composed for every policy at the largest smoke size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn, write_bench_json
from repro.core.engine import neg_log_count
from repro.core.likelihood import IntensityModel
from repro.core.precision import get_policy
from repro.kernels.logsumexp import ops as lse_ops
from repro.kernels.resample import ops as res_ops


def _naive_chain(log_w, key):
    # separate "kernels", mirroring the paper's max/weight/normalize split
    m = jax.jit(jnp.max)(log_w)
    w = jax.jit(jnp.exp)(log_w - m)
    s = jax.jit(jnp.sum)(w)
    wn = jax.jit(jnp.divide)(w, s)
    cdf = jax.jit(jnp.cumsum)(wn)
    u0 = jax.random.uniform(key, (), jnp.float32)
    n = log_w.shape[0]
    u = (jnp.arange(n, dtype=jnp.float32) + u0) / n
    anc = jax.jit(jnp.searchsorted, static_argnames="side")(cdf, u, side="right")
    return anc


@jax.jit
def _fused_chain(log_w, key):
    w, m, lse = lse_ops.normalize_weights(log_w)
    return res_ops.systematic_resample(key, w)


def run(n: int = 8192) -> list[str]:
    rows = []
    for pname in ["fp32", "fp16", "bf16"]:
        pol = get_policy(pname)
        log_w = (
            jax.random.normal(jax.random.key(0), (n,), jnp.float32) * 20
        ).astype(pol.compute_dtype)
        key = jax.random.key(1)
        us_naive = time_fn(_naive_chain, log_w, key, reps=5)
        us_fused = time_fn(_fused_chain, log_w, key, reps=5)
        rows.append(
            csv_row(
                f"fig6_kernels/naive_{pname}", us_naive,
                f"n={n};kernels=7",
            )
        )
        rows.append(
            csv_row(
                f"fig6_kernels/fused_{pname}", us_fused,
                f"n={n};kernels=2;speedup={us_naive/us_fused:.2f}",
            )
        )
    rows.extend(step_sweep())
    return rows


def step_sweep(
    sizes=(8_192, 32_768),
    policies=("fp32", "bf16", "fp16"),
    bank: int = 4,
    reps: int = 7,
    gate: bool = False,
) -> list[str]:
    """Fused full-step kernel vs the composed chain, per policy x P.

    Per cell: the per-frame likelihood → weights → resample pipeline of a
    B-row bank on pre-gathered (B, P, J) patches — the patch gather is
    identical either way and excluded.  The composed variant runs the
    Pallas likelihood kernel, the XLA weight add (constant uniform prior),
    and the one-pass fused epilogue — the engine's best pre-fusion path;
    the fused variant runs the single streaming step kernel.  Same keys ⇒
    bitwise-identical outputs (tests/test_step.py), so the delta is the
    composed chain's extra HBM round-trips of the (B, P) log-likelihood
    and log-weight arrays (see ``roofline --step`` for the traffic model).

    ``gate=True`` (the CI smoke) raises SystemExit if fused is slower
    than composed for *any* policy at the largest size.
    """
    import numpy as np

    from repro.kernels.epilogue import ops as epi_ops
    from repro.kernels.likelihood import ops as lik_ops
    from repro.kernels.step import ops as step_ops

    model = IntensityModel(radius=4)
    j = model.num_points
    rows, records = [], []
    gate_min = None
    for n in sizes:
        for pname in policies:
            pol = get_policy(pname)
            cdt = pol.compute_dtype
            keys = jax.random.split(jax.random.key(0), bank)
            patches = jax.random.uniform(
                jax.random.key(1), (bank, n, j), jnp.float32, 60.0, 250.0
            )
            prior = jnp.full((bank,), neg_log_count(n, cdt))

            @jax.jit
            def composed_step(keys, patches, prior):
                ll = jax.vmap(
                    lambda p: lik_ops.intensity_loglik(p, model, pol)
                )(patches).astype(cdt)
                log_w = prior[:, None] + ll
                return epi_ops.fused_epilogue_batched(keys, log_w)

            @jax.jit
            def fused_step(keys, patches, prior):
                return step_ops.fused_step_batched(
                    keys, patches, model, prior, pol
                )

            us = {
                "composed": time_fn(
                    composed_step, keys, patches, prior, reps=reps, warmup=1
                ),
                "fused": time_fn(
                    fused_step, keys, patches, prior, reps=reps, warmup=1
                ),
            }
            speedup = us["composed"] / us["fused"]
            if n == max(sizes):
                gate_min = (
                    speedup if gate_min is None else min(gate_min, speedup)
                )
            rows.append(
                csv_row(
                    f"fig6_kernels/step_B{bank}_{n//1024}k_{pname}",
                    us["fused"],
                    f"composed_us={us['composed']:.1f};"
                    f"speedup_fused_vs_composed={speedup:.2f}",
                )
            )
            records.append(
                {
                    "bank": bank,
                    "particles": n,
                    "policy": pname,
                    "disk_points": j,
                    "us_per_step_fused": us["fused"],
                    "us_per_step_composed": us["composed"],
                    "particle_steps_per_s_fused": (
                        bank * n / us["fused"] * 1e6
                    ),
                    "speedup_fused_vs_composed": speedup,
                }
            )
    write_bench_json(
        "fig6",
        records,
        largest_size=max(sizes),
        largest_size_min_speedup=gate_min,
    )
    if gate and gate_min is not None and gate_min < 1.0:
        raise SystemExit(
            f"fused step slower than composed chain at P={max(sizes)}: "
            f"min speedup={gate_min:.2f} < 1.0 (see BENCH_fig6.json)"
        )
    return rows


def step_smoke() -> list[str]:
    """CI entry: quick step sweep that *gates* on fused >= composed
    throughput (every policy) at the largest smoke size."""
    return step_sweep(sizes=(8_192, 32_768), reps=7, gate=True)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "run"
    fns = {"run": run, "step_sweep": step_sweep, "step_smoke": step_smoke}
    print("name,us_per_call,derived")
    for row in fns[which]():
        print(row)
