"""Paper Fig. 8: threads-per-block sweep -> Pallas BlockSpec sweep.

The TPU analogue of CUDA launch geometry is the BlockSpec block shape: it
fixes the VMEM working set and the grid sequentialization.  CPU interpret
timing is not hardware-meaningful, so the derived metrics are structural:
VMEM bytes per grid step and grid length, plus a numerical-equivalence
check across the sweep (results must be launch-geometry invariant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.kernels.logsumexp import ops as lse_ops


def run(n: int = 65_536) -> list[str]:
    x = (jax.random.normal(jax.random.key(0), (n,), jnp.float32) * 30).astype(
        jnp.float16
    )
    ref = None
    rows = []
    for block_rows in [8, 16, 32, 64, 128, 256]:
        us = time_fn(
            lambda x: lse_ops.normalize_weights(x, block_rows=block_rows),
            x,
            reps=3,
            warmup=1,
        )
        w, m, lse = lse_ops.normalize_weights(x, block_rows=block_rows)
        if ref is None:
            ref = np.asarray(lse)
        np.testing.assert_allclose(np.asarray(lse), ref, rtol=1e-6)
        vmem_kib = block_rows * 128 * 2 * 2 / 1024  # in+out blocks, fp16
        grid = (n + block_rows * 128 - 1) // (block_rows * 128)
        rows.append(
            csv_row(
                f"fig8_blocksweep/rows{block_rows}",
                us,
                f"vmem_kib={vmem_kib:.0f};grid_steps={2 * grid}",
            )
        )
    return rows
