"""Pluggable lint rules — one module per historical bug class.

A rule is an object with

- ``name``       — kebab-case id, the pragma / baseline key,
- ``motivation`` — the PR/bug that made the invariant worth machine-checking
  (rendered by the CLI rule table and the README),
- and either ``check_file(rel_path, tree, source) -> [Finding]`` (AST rules,
  run per matching file) with a ``matches(rel_path) -> bool`` scope, or
  ``check_repo() -> [Finding]`` (registry rules, run once against the live
  imported registries).

Register with :func:`register_rule`; the lint engine iterates ``RULES``.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

__all__ = [
    "LintRule",
    "RULES",
    "register_rule",
    "dotted_name",
    "line_finding",
    "walk_calls",
]

RULES: dict[str, "LintRule"] = {}


class LintRule:
    """Base class: scope + one of the two check hooks."""

    name: str = ""
    motivation: str = ""

    def matches(self, rel_path: str) -> bool:
        return True

    def check_file(
        self, rel_path: str, tree: ast.AST, source: str
    ) -> list[Finding]:
        return []

    def check_repo(self) -> list[Finding]:
        return []


def register_rule(rule: LintRule) -> LintRule:
    if not rule.name:
        raise ValueError("rule needs a name")
    RULES[rule.name] = rule
    return rule


def dotted_name(node: ast.AST) -> str:
    """'jnp.cumsum' for Attribute/Name chains, '' for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def walk_calls(tree: ast.AST):
    """Yield (call_node, dotted callee name) for every Call in the tree."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node, dotted_name(node.func)


def line_finding(
    rule: "LintRule",
    rel_path: str,
    source: str,
    node: ast.AST,
    message: str,
) -> Finding:
    lines = source.splitlines()
    line = getattr(node, "lineno", 0)
    snippet = lines[line - 1].strip() if 0 < line <= len(lines) else ""
    return Finding(
        rule=rule.name,
        path=rel_path,
        line=line,
        message=message,
        snippet=snippet,
    )


# Import order = report order; each module registers its rule(s) on import.
from repro.analysis.rules import (  # noqa: E402,F401
    shared_body,
    masked_grid,
    donation_safety,
    host_log,
    dtype_literals,
    registry_completeness,
)
