"""dtype-literal: bare 16-bit dtype literals only in the precision modules.

Motivation (the paper's whole premise): which arrays live in fp16/bf16 is a
*policy* decision — ``PrecisionPolicy`` threads param/compute/accum dtypes
through every layer precisely so precision can be swept, tested, and audited
in one place.  A bare ``jnp.float16`` / ``jnp.bfloat16`` literal elsewhere
is a precision decision the policy cannot see, sweep, or override — the
exact erosion channel Murray's Metropolis analysis warns about.  Blessed
homes: ``core/precision.py`` (the policy definitions) and
``core/stability.py`` (the mitigation toolbox).  Anything else is either a
bug or a documented, pragma'd design choice.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import (
    LintRule,
    dotted_name,
    line_finding,
    register_rule,
)

_HALF_ATTRS = {"float16", "bfloat16", "half"}
_BLESSED = {
    "src/repro/core/precision.py",
    "src/repro/core/stability.py",
}


class DtypeLiteralRule(LintRule):
    name = "dtype-literal"
    motivation = (
        "paper core: 16-bit placement is PrecisionPolicy's decision; a "
        "bare half literal is a precision choice no policy can sweep or "
        "audit"
    )

    def matches(self, rel_path: str) -> bool:
        return (
            rel_path.startswith("src/repro/") and rel_path not in _BLESSED
        )

    def check_file(self, rel_path, tree, source):
        findings = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            if node.attr not in _HALF_ATTRS:
                continue
            name = dotted_name(node)
            base = name.rpartition(".")[0]
            if base not in ("jnp", "np", "jax.numpy", "numpy"):
                continue
            findings.append(
                line_finding(
                    self,
                    rel_path,
                    source,
                    node,
                    f"bare `{name}` dtype literal outside "
                    "core/precision.py|core/stability.py — thread the "
                    "dtype through PrecisionPolicy (param/compute/accum) "
                    "or pragma the documented design choice",
                )
            )
        return findings


register_rule(DtypeLiteralRule())
