"""donation-safety: zero-copy views must not escape scheduler code.

Motivation (PR 5, the retire pin): ``np.asarray(jax_array)`` on CPU is a
*zero-copy view* into the jax buffer.  A scheduler that stores such a view
in a result dict / list (or returns it) keeps the underlying bank buffer
alive for the rest of the run — which silently blocks every later donated
step/reset from aliasing the bank state in place, and pins the whole
``(slots, P, steps)`` array per retired request.  Local temporaries that die
with the function frame are fine; what this rule flags is a view *escaping*:
``np.asarray(...)`` (or ``x.view(...)``) appearing directly inside a
container literal, as an ``append``/``extend``/``insert`` argument, or in a
``return`` whose function is scheduler code.  The fix is an explicit copy —
``np.array(...)`` — at the escape point.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import (
    LintRule,
    dotted_name,
    line_finding,
    register_rule,
)

_VIEW_CALLEES = {"np.asarray", "numpy.asarray", "onp.asarray"}
_SINK_METHODS = {"append", "extend", "insert"}


def _is_view_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    if name in _VIEW_CALLEES:
        return True
    # method-style zero-copy reinterpret: x.view(...)
    return (
        isinstance(node.func, ast.Attribute) and node.func.attr == "view"
    )


def _direct_view_elements(node: ast.AST):
    """View calls sitting directly in a container literal (any nesting of
    literals, but not through arbitrary calls)."""
    stack = [node]
    while stack:
        n = stack.pop()
        if _is_view_call(n):
            yield n
        elif isinstance(n, (ast.List, ast.Tuple, ast.Set)):
            stack.extend(n.elts)
        elif isinstance(n, ast.Dict):
            stack.extend(v for v in n.values if v is not None)


class DonationSafetyRule(LintRule):
    name = "donation-safety"
    motivation = (
        "PR-5: np.asarray is a zero-copy view into the jax buffer; a view "
        "escaping the scheduler pins bank state and blocks donation"
    )

    def matches(self, rel_path: str) -> bool:
        return rel_path.startswith("src/repro/launch/")

    def check_file(self, rel_path, tree, source):
        findings = []

        def flag(node, how):
            findings.append(
                line_finding(
                    self,
                    rel_path,
                    source,
                    node,
                    f"zero-copy view escapes the scheduler ({how}) — it "
                    "pins the donated bank buffers for the rest of the "
                    "run; copy with np.array(...) at the escape point",
                )
            )

        for node in ast.walk(tree):
            # {..: np.asarray(x)} / [np.asarray(x), ...] anywhere
            if isinstance(node, (ast.List, ast.Tuple, ast.Set, ast.Dict)):
                for v in _direct_view_elements(node):
                    flag(v, "stored in a container literal")
            # results.append(np.asarray(x)) and friends
            elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute
            ):
                if node.func.attr in _SINK_METHODS:
                    for arg in node.args:
                        if _is_view_call(arg):
                            flag(arg, f"passed to .{node.func.attr}()")
                        else:
                            for v in _direct_view_elements(arg):
                                flag(v, f"passed to .{node.func.attr}()")
            # return np.asarray(x)
            elif isinstance(node, ast.Return) and node.value is not None:
                if _is_view_call(node.value):
                    flag(node.value, "returned")
                else:
                    for v in _direct_view_elements(node.value):
                        flag(v, "returned")
        return findings


register_rule(DonationSafetyRule())
