"""shared-body: kernel files must reduce/scan/bisect through the shared
bodies in ``repro/kernels/common.py``.

Motivation (PR 5 / PR 7): the fused kernels are bitwise-identical to the
composed likelihood→logsumexp→resample chain *only because* both sides
execute the same op sequences — ``pairwise_sum``, ``cdf_block``,
``bisect_flat``, ``online_lse_block``, ``loglik_rows``.  A kernel file that
re-derives a prefix sum with raw ``jnp.cumsum``, inverts a CDF with
``searchsorted``, or hand-rolls a max-subtracted logsumexp forks that
contract: XLA is free to reassociate its reduction differently per shape and
fusion context, and the fork only surfaces as a 1-ulp cross-backend
mismatch months later.  The pure-jnp oracles (``ref.py``) are *intentionally*
independent implementations — they carry pragmas, not exemptions, so the
independence stays a documented decision.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import (
    LintRule,
    dotted_name,
    line_finding,
    register_rule,
    walk_calls,
)

# Raw calls that fork a shared body, and the body they should go through.
_FORKS = {
    "cumsum": "kernels.common.cdf_block (blockwise-carry inclusive cumsum)",
    "searchsorted": "kernels.common.bisect_flat (flat-CDF bisection)",
}
_NAMESPACES = ("jnp", "np", "jax.numpy", "numpy", "jax.lax", "lax")


class SharedBodyRule(LintRule):
    name = "shared-body"
    motivation = (
        "PR-5/7: fused == composed is bitwise only because every kernel "
        "folds through the one reduction/scan/bisect body in "
        "kernels/common.py"
    )

    def matches(self, rel_path: str) -> bool:
        return (
            rel_path.startswith("src/repro/kernels/")
            and rel_path != "src/repro/kernels/common.py"
        )

    def check_file(self, rel_path, tree, source):
        findings = []
        for call, callee in walk_calls(tree):
            base, _, attr = callee.rpartition(".")
            if attr in _FORKS and base in _NAMESPACES:
                findings.append(
                    line_finding(
                        self,
                        rel_path,
                        source,
                        call,
                        f"raw `{callee}` in a kernel file forks the bitwise "
                        f"contract — fold through {_FORKS[attr]} instead",
                    )
                )
        findings += self._manual_lse(rel_path, tree, source)
        return findings

    def _manual_lse(self, rel_path, tree, source):
        """Flag hand-rolled log(sum(exp(...))) chains — the online-LSE body
        (`kernels.common.online_lse_block`) or `stability.logsumexp` are the
        two blessed spellings."""
        out = []
        for call, callee in walk_calls(tree):
            if callee.rpartition(".")[2] != "log":
                continue
            sums = [
                inner
                for inner, iname in walk_calls(call)
                if iname.rpartition(".")[2] == "sum" and inner is not call
            ]
            for s in sums:
                if any(
                    iname.rpartition(".")[2] == "exp"
                    for inner, iname in walk_calls(s)
                    if inner is not s
                ):
                    out.append(
                        line_finding(
                            self,
                            rel_path,
                            source,
                            call,
                            "hand-rolled log(sum(exp(...))) logsumexp in a "
                            "kernel file — use kernels.common."
                            "online_lse_block or stability.logsumexp",
                        )
                    )
                    break
        return out


register_rule(SharedBodyRule())
