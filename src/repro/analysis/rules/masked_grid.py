"""masked-grid: count-aware resampling grids in ragged code paths.

Motivation (PR 4): a ragged bank's resampler must build its u-grid over the
*active* count — ``u = (g + u0) / n_active`` — because inactive lanes carry
exactly-zero weight and the CDF is flat past the active prefix.  A dense
``1/P`` grid truncated by the mask never probes the top of the active CDF:
only draws below ``n_active/P`` survive, silently biasing resampling toward
the low-CDF prefix (the bug class ``resampling.MASKED_RESAMPLERS`` exists to
prevent; see its registry-completeness twin).  This rule flags any function
that receives an active-count argument yet divides an ``arange``/``iota``
grid by something *not* derived from that count.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import (
    LintRule,
    dotted_name,
    line_finding,
    register_rule,
)

_COUNT_ARGS = {"n_active", "n_loc", "n_act"}
_ROW_COUNT_ARGS = {"n", "k", "count", "cnt"}
_GRID_FNS = {"arange", "iota", "broadcasted_iota"}


def _mentions(node: ast.AST, names: set[str]) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in names for n in ast.walk(node)
    )


def _has_grid_call(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Call)
        and dotted_name(n.func).rpartition(".")[2] in _GRID_FNS
        for n in ast.walk(node)
    )


class MaskedGridRule(LintRule):
    name = "masked-grid"
    motivation = (
        "PR-4: a dense 1/P u-grid under a lane mask never samples the top "
        "of the active CDF — grids must span n_active"
    )

    def matches(self, rel_path: str) -> bool:
        return rel_path.startswith("src/repro/")

    def check_file(self, rel_path, tree, source):
        findings = []
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = {
                a.arg
                for a in (
                    fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs
                )
            }
            counts = args & _COUNT_ARGS
            if not counts:
                continue
            # Count-derived names: the count args plus anything assigned
            # from an expression mentioning one (one level of dataflow is
            # enough for the grid-idiom bodies this repo writes).
            derived = set(counts)
            # vmapped per-row closures rebind the count under a short name
            # (`def row(key, w, n)`): count-ish params of nested defs count.
            for node in ast.walk(fn):
                if (
                    isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node is not fn
                ):
                    for a in node.args.args:
                        if a.arg in _ROW_COUNT_ARGS:
                            derived.add(a.arg)
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and _mentions(
                    node.value, derived
                ):
                    for tgt in node.targets:
                        for n in ast.walk(tgt):
                            if isinstance(n, ast.Name):
                                derived.add(n.id)
            for node in ast.walk(fn):
                if not (
                    isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.Div)
                ):
                    continue
                if not _has_grid_call(node.left):
                    continue
                if _mentions(node.right, derived) or _has_grid_call(
                    node.right
                ):
                    continue
                findings.append(
                    line_finding(
                        self,
                        rel_path,
                        source,
                        node,
                        f"function takes {sorted(counts)} but divides its "
                        "u-grid by a count-independent width — a dense 1/P "
                        "grid under a mask never samples the top of the "
                        "active CDF (use the MASKED_RESAMPLERS idiom: "
                        "grid / n_active)",
                    )
                )
        return findings


register_rule(MaskedGridRule())
