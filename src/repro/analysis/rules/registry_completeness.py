"""registry-completeness: the live registries must be closed under the
engine's dispatch rules.

Motivation (PR 4/6/7 init-time raises, promoted to lint time): the engine
*runtime*-raises when a ragged bank meets a resampler with no masked form,
or a meshed fused step meets a backend without its finalize twin — but only
on the first request that hits that path.  This rule audits the imported
registries themselves so the gap is a CI failure, not a 3 a.m. serve crash:

- every ``register_resampler`` name has a count-aware
  ``MASKED_RESAMPLERS`` entry or an explicit ``MASKED_OPT_OUTS`` opt-out
  (dense grids under a mask bias resampling — the PR-4 class);
- every registered resampler has the auto-derived fused references
  (``FUSED_EPILOGUES*`` / ``FUSED_STEPS*`` — poking ``RESAMPLERS`` directly
  instead of calling ``register_resampler`` skips them);
- every registered :class:`~repro.core.engine.Backend` implements a
  *consistent hook matrix*: within each fused family (epilogue, step,
  finalize, step-finalize) the per-resampler key sets of the
  base/banked/masked variants must match — a name with a banked form but no
  masked twin silently de-fuses ragged banks (or raises at ragged init);
  likewise ``resamplers`` vs ``resamplers_masked`` and the
  normalize/normalize_masked pairing.
"""

from __future__ import annotations

from repro.analysis.findings import Finding
from repro.analysis.rules import LintRule, register_rule

_RESAMPLING = "src/repro/core/resampling.py"
_ENGINE = "src/repro/core/engine.py"


class RegistryCompletenessRule(LintRule):
    name = "registry-completeness"
    motivation = (
        "PR-4/6/7: registry gaps (no masked form, missing fused twin) "
        "surface today as init/serve-time raises — catch them at lint time"
    )

    def matches(self, rel_path: str) -> bool:
        return False  # repo rule: runs once, not per file

    def check_repo(self) -> list[Finding]:
        from repro.core import engine, resampling

        findings: list[Finding] = []

        def add(path, msg):
            findings.append(
                Finding(rule=self.name, path=path, line=0, message=msg)
            )

        opt_outs = getattr(resampling, "MASKED_OPT_OUTS", set())
        for name in sorted(resampling.RESAMPLERS):
            if (
                name not in resampling.MASKED_RESAMPLERS
                and name not in opt_outs
            ):
                add(
                    _RESAMPLING,
                    f"resampler {name!r} has no MASKED_RESAMPLERS entry "
                    "and no MASKED_OPT_OUTS opt-out — it cannot run "
                    "ragged, and a dense grid under a mask would bias "
                    "resampling (PR-4)",
                )
            for reg, label in (
                (resampling.FUSED_EPILOGUES, "FUSED_EPILOGUES"),
                (resampling.FUSED_EPILOGUES_BANKED, "FUSED_EPILOGUES_BANKED"),
                (resampling.FUSED_STEPS, "FUSED_STEPS"),
                (resampling.FUSED_STEPS_BANKED, "FUSED_STEPS_BANKED"),
            ):
                if name not in reg:
                    add(
                        _RESAMPLING,
                        f"resampler {name!r} missing from {label} — was it "
                        "registered by poking RESAMPLERS directly instead "
                        "of register_resampler()?",
                    )
            if name in resampling.MASKED_RESAMPLERS:
                for reg, label in (
                    (resampling.FUSED_EPILOGUES_MASKED,
                     "FUSED_EPILOGUES_MASKED"),
                    (resampling.FUSED_STEPS_MASKED, "FUSED_STEPS_MASKED"),
                ):
                    if name not in reg:
                        add(
                            _RESAMPLING,
                            f"resampler {name!r} has a masked form but no "
                            f"{label} reference — ragged banks de-fuse "
                            "silently",
                        )

        for bname, backend in sorted(engine.BACKENDS.items()):
            loc = f"backend {bname!r}"
            families = {
                "fused_epilogue": (
                    backend.fused_epilogue,
                    backend.fused_epilogue_banked,
                    backend.fused_epilogue_masked,
                ),
                "fused_step": (
                    backend.fused_step,
                    backend.fused_step_banked,
                    backend.fused_step_masked,
                ),
                "fused_finalize": (
                    backend.fused_finalize_banked,
                    backend.fused_finalize_masked,
                ),
                "fused_step_finalize": (
                    backend.fused_step_finalize_banked,
                    backend.fused_step_finalize_masked,
                ),
                "resamplers": (
                    backend.resamplers,
                    backend.resamplers_banked,
                    backend.resamplers_masked,
                ),
            }
            for fam, variants in families.items():
                keysets = [set(v or {}) for v in variants]
                union = set().union(*keysets)
                for i, ks in enumerate(keysets):
                    missing = union - ks
                    if missing:
                        add(
                            _ENGINE,
                            f"{loc}: {fam} hook matrix is ragged — "
                            f"variant {i} lacks {sorted(missing)} (a name "
                            "with a banked form but no masked twin "
                            "de-fuses ragged banks or raises at init)",
                        )
            # normalize family: a backend with masked plain-normalize but
            # no masked stats form silently re-reads (B, P) weights for ESS.
            if (
                backend.normalize_masked is not None
                and backend.normalize_stats_banked is not None
                and backend.normalize_stats_masked is None
            ):
                add(
                    _ENGINE,
                    f"{loc}: has normalize_masked and "
                    "normalize_stats_banked but no normalize_stats_masked "
                    "— ragged banks fall back to a second (B, P) weight "
                    "traversal for ESS",
                )
        return findings


register_rule(RegistryCompletenessRule())
