"""host-log: log-of-count folding outside the blessed host-double path.

Motivation (PR 4, the 1-ulp constant-folded log): XLA's constant-folded
``log`` and its runtime vectorized ``log`` differ by 1 ulp for some particle
counts, and Python-host ``math.log``/``np.log`` add a third rounding path.
The repo's uniform log-weight ``-log(n)`` must be bit-stable across every
call site in a slot's lifetime, which is why the ONE blessed spelling lives
in ``repro/core/engine.py`` (``_neg_log_count``: host-double log for
concrete counts — exactly the bits of the dense ``-jnp.log(float(P))``
constant — runtime fp32 log for traced counts, and the result *stored* per
slot rather than recomputed).  This rule flags, outside ``core/engine.py``:

- host ``math.log`` / ``np.log`` calls (any argument — host folding is the
  hazard, whatever is being logged), and
- ``jnp.log`` of a Python constant (``jnp.log(float(...))``, ``jnp.log(8)``)
  — a compile-time fold that need not match the runtime log of the same
  count.

Sites that *deliberately* reproduce the dense constant's bits (the meshed
uniform resets in ``core/distributed.py``) carry pragmas saying so.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import (
    LintRule,
    dotted_name,
    line_finding,
    register_rule,
    walk_calls,
)

_HOST_LOGS = {"math.log", "math.log2", "np.log", "np.log2", "numpy.log"}
_JNP_LOGS = {"jnp.log", "jax.numpy.log"}


def _is_constanty(node: ast.AST) -> bool:
    """A compile-time-foldable scalar: literal, float()/int() cast, or
    unary/binary arithmetic over such."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float))
    if isinstance(node, ast.UnaryOp):
        return _is_constanty(node.operand)
    if isinstance(node, ast.BinOp):
        return _is_constanty(node.left) and _is_constanty(node.right)
    if isinstance(node, ast.Call) and dotted_name(node.func) in (
        "float",
        "int",
    ):
        return True
    return False


class HostLogRule(LintRule):
    name = "host-log"
    motivation = (
        "PR-4: folded, runtime, and host logs of the same count differ by "
        "1 ulp — -log(n) must come from the one blessed path in core/engine"
    )

    def matches(self, rel_path: str) -> bool:
        if rel_path == "src/repro/core/engine.py":  # the blessed path
            return False
        return rel_path.startswith(
            ("src/repro/core/", "src/repro/kernels/", "src/repro/launch/")
        ) or rel_path.startswith("benchmarks/")

    def check_file(self, rel_path, tree, source):
        findings = []
        for call, callee in walk_calls(tree):
            if callee in _HOST_LOGS:
                findings.append(
                    line_finding(
                        self,
                        rel_path,
                        source,
                        call,
                        f"host `{callee}` — a third rounding of log(n) "
                        "beside XLA's folded and runtime logs; route "
                        "particle-count logs through "
                        "repro.core.engine.neg_log_count",
                    )
                )
            elif callee in _JNP_LOGS and call.args and _is_constanty(
                call.args[0]
            ):
                findings.append(
                    line_finding(
                        self,
                        rel_path,
                        source,
                        call,
                        "constant-folded `jnp.log(<const>)` — XLA's folded "
                        "log differs from its runtime log by 1 ulp for "
                        "some counts; use repro.core.engine.neg_log_count "
                        "(or pragma why these exact bits are intended)",
                    )
                )
        return findings


register_rule(HostLogRule())
