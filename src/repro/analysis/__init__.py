"""Precision-contract analyzer for the half-precision filter stack.

Two engines, one CLI (``python -m repro.analysis``):

- :mod:`repro.analysis.lint` — AST rules over ``src/repro`` +
  ``benchmarks``, each seeded by a historical bug class (shared kernel
  bodies, masked grids, donation-safe schedulers, the blessed host-log
  path, dtype-literal containment, registry completeness).
- :mod:`repro.analysis.jaxpr_audit` — traces the real jitted entry points
  under each precision policy and checks the jaxprs/compiled artifacts:
  fp32 accumulation, stability-mediated transcendentals, donation
  aliasing, recompile-free budget transitions.

Known-and-accepted findings live in ``baseline.json`` (content-fingerprint
suppression); per-line opt-outs use ``# analysis: allow(<rule>): why``.
"""

from repro.analysis.findings import (
    Finding,
    baseline_path,
    load_baseline,
    split_baseline,
    write_baseline,
)
from repro.analysis.lint import run_lint
from repro.analysis.rules import RULES

__all__ = [
    "Finding",
    "RULES",
    "baseline_path",
    "load_baseline",
    "run_lint",
    "split_baseline",
    "write_baseline",
]
