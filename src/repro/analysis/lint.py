"""Engine 1: the AST lint pass.

Walks the repo's Python sources (``src/repro`` + ``benchmarks``), parses
each file once, and runs every registered rule whose scope matches.  Pragma
suppression (``# analysis: allow(<rule>): why``) is applied per file;
repo-level rules (live-registry audits) run once at the end.  Findings come
back un-baselined — the CLI owns baseline semantics.
"""

from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding, apply_pragmas
from repro.analysis.rules import RULES

__all__ = ["repo_root", "iter_source_files", "lint_file", "run_lint"]

_DEFAULT_ROOTS = ("src/repro", "benchmarks")


def repo_root() -> str:
    """The checkout root (three levels above this package)."""
    here = os.path.dirname(os.path.abspath(__file__))  # src/repro/analysis
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def iter_source_files(root: str, subdirs=_DEFAULT_ROOTS):
    for sub in subdirs:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(
                d for d in dirnames if d != "__pycache__"
            )
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_file(
    path: str, rel_path: str, rules=None, source: str | None = None
) -> list[Finding]:
    """All findings for one file, pragma-filtered, in line order."""
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:
        return [
            Finding(
                rule="parse",
                path=rel_path,
                line=e.lineno or 0,
                message=f"syntax error: {e.msg}",
            )
        ]
    findings: list[Finding] = []
    for rule in (rules if rules is not None else RULES.values()):
        if rule.matches(rel_path):
            findings.extend(rule.check_file(rel_path, tree, source))
    findings = apply_pragmas(findings, source, rel_path)
    # A container inside an .append() can legitimately match two escape
    # patterns — report each violation site once.
    seen, unique = set(), []
    for f in findings:
        key = (f.rule, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return sorted(unique, key=lambda f: (f.line, f.rule))


def run_lint(
    root: str | None = None, rules=None, with_repo_rules: bool = True
) -> list[Finding]:
    """Lint the whole tree; repo rules (live-registry audits) run once."""
    root = root or repo_root()
    rule_list = list(rules if rules is not None else RULES.values())
    findings: list[Finding] = []
    for path in iter_source_files(root):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        findings.extend(lint_file(path, rel, rules=rule_list))
    if with_repo_rules:
        for rule in rule_list:
            findings.extend(rule.check_repo())
    return findings
