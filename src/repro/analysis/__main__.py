"""CLI: ``python -m repro.analysis [--check] [--no-jaxpr] ...``.

Default run prints every finding (baseline-suppressed ones marked).  With
``--check``, exits 1 iff there are findings *not* in the baseline — the CI
gate: new violations fail, the audited-and-accepted set doesn't.
``--write-baseline`` refreshes ``baseline.json`` from the current tree
(review the diff — a growing baseline is a smell).
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import (
    baseline_path,
    load_baseline,
    split_baseline,
    write_baseline,
)
from repro.analysis.lint import run_lint
from repro.analysis.rules import RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="precision-contract analyzer (AST lint + jaxpr audit)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit 1 on findings not in the baseline (the CI gate)",
    )
    ap.add_argument(
        "--no-jaxpr",
        action="store_true",
        help="AST lint only — skip the (slower) jaxpr/compiled audit",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="accept the current tree: rewrite baseline.json",
    )
    ap.add_argument(
        "--baseline",
        default=None,
        metavar="PATH",
        help=f"baseline file (default: {baseline_path()})",
    )
    ap.add_argument(
        "--rules",
        action="store_true",
        help="list registered AST rules and exit",
    )
    ap.add_argument(
        "-q", "--quiet", action="store_true", help="counts only"
    )
    args = ap.parse_args(argv)

    if args.rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:24s} {rule.motivation}")
        return 0

    findings = run_lint()
    log: list[str] = []
    if not args.no_jaxpr:
        from repro.analysis.jaxpr_audit import run_audit

        audit_findings, log = run_audit()
        findings.extend(audit_findings)

    bpath = args.baseline or baseline_path()
    if args.write_baseline:
        write_baseline(findings, bpath)
        print(f"wrote {len(findings)} finding(s) to {bpath}")
        return 0

    baseline = load_baseline(bpath)
    new, suppressed = split_baseline(findings, baseline)

    if not args.quiet:
        for line in log:
            print(f"# {line}")
        for f in new:
            print(f.format())
        for f in suppressed:
            print(f"{f.format()}  [baseline]")
    print(
        f"{len(new)} new finding(s), {len(suppressed)} baseline-suppressed, "
        f"{len(RULES)} rules"
        + ("" if args.no_jaxpr else f", {len(log)} audit log line(s)")
    )
    if args.check and new:
        print(
            "FAIL: new findings — fix them, pragma with justification "
            "(# analysis: allow(<rule>): why), or --write-baseline after "
            "review",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
