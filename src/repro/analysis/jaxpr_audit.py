"""Engine 2: the jaxpr / compiled-artifact auditor.

The AST rules read source; this engine checks what the compiler actually
sees.  It traces the real jitted entry points — ``ParticleFilter.step``, the
``FilterBank`` fused step (dense and ragged/masked), ``resize_slot`` — under
each precision policy and walks the jaxprs (including the inner jaxprs of
every ``pallas_call``) to enforce the numerics contract structurally:

- **half-accum**: no reduction/contraction primitive (``reduce_sum``,
  ``cumsum``, ``dot_general``, ...) produces a 16-bit result, and no
  ``scan`` carries a 16-bit float — accumulation happens in fp32 even when
  inputs are fp16/bf16.  Enforced everywhere for fp32-accum policies
  (``fp32``/``bf16_mixed``/``fp16_mixed``/``bf16_w8``) and inside Pallas
  kernel bodies for *pure* half policies (the kernels' blockwise carries are
  fp32 by the shared-body contract even when the engine-level story is
  16-bit).
- **half-explog**: no ``exp``/``log`` family primitive runs at 16 bits
  unless it is *stability-mediated* — reachable (backwards through the
  dataflow) from a max-subtraction, i.e. the ``log∘sum∘exp(x - max)``
  shapes that ``core/stability.py`` emits.  A naive ``exp(log_w)`` has no
  ``sub``/``reduce_max`` upstream and is flagged.
- **donation**: every donated entry point's compiled executable aliases
  >= 0.9x the state bytes input->output (``memory_analysis``), and the
  undonated twin aliases nothing.
- **recompile**: ragged budget transitions (``resize_slot`` across distinct
  (slot, count) pairs) hit one compile-cache entry — counts stay traced.

Pure half policies on the ``jnp`` backend are *skipped by design*: the
paper-faithful reference deliberately accumulates in ``accum_dtype`` (the
16-bit CDF build is the artifact under study, not a bug).  The ``*_naive``
policies are likewise exempt — they exist to reproduce the failure mode.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding

__all__ = [
    "audit_closed_jaxpr",
    "audit_dtypes",
    "audit_donation",
    "audit_recompile",
    "run_audit",
    "STRICT_POLICIES",
    "KERNEL_ONLY_POLICIES",
]

# Policies whose accum dtype is fp32: the contract is strict everywhere.
STRICT_POLICIES = ("fp32", "bf16_mixed", "fp16_mixed", "bf16_w8")
# Pure half policies: strict inside pallas kernel bodies, mediation-checked
# exp/log at the engine level; jnp backend skipped (paper-faithful).
KERNEL_ONLY_POLICIES = ("fp16", "bf16")

_HALF_NAMES = ("float16", "bfloat16")
_ACCUM_PRIMS = {
    "reduce_sum",
    "cumsum",
    "cumprod",
    "reduce_prod",
    "dot_general",
    "add_any",
}
_EXPLOG_PRIMS = {"exp", "log", "exp2", "log2", "log1p", "expm1", "logistic"}
_MEDIATORS = {"reduce_max", "max", "cummax"}

# Tiny tracker spec: tracing only needs shapes, so keep compiles cheap.
_P, _H, _W = 64, 32, 32


def _is_half(aval: Any) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and getattr(dt, "name", "") in _HALF_NAMES


def _sub_jaxprs(value: Any):
    """Inner jaxprs hiding in an eqn's params (pallas_call / scan / cond
    bodies), whatever container they sit in."""
    if hasattr(value, "eqns"):  # Jaxpr
        yield value
    elif hasattr(value, "jaxpr"):  # ClosedJaxpr
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _sub_jaxprs(v)


def _walk(jaxpr: Any, visit: Callable, in_kernel: bool = False) -> None:
    for eqn in jaxpr.eqns:
        visit(eqn, jaxpr, in_kernel)
        inner_kernel = in_kernel or "pallas" in eqn.primitive.name
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, visit, inner_kernel)


def _reachable_prims(eqn: Any, defs: dict, depth: int = 24) -> set[str]:
    """Primitive names reachable backwards from ``eqn``'s inputs within the
    same jaxpr level (bounded breadth-first walk over defining eqns)."""
    seen_vars: set[int] = set()
    prims: set[str] = set()
    frontier = list(eqn.invars)
    for _ in range(depth):
        if not frontier:
            break
        next_frontier = []
        for v in frontier:
            vid = id(v)
            if vid in seen_vars:
                continue
            seen_vars.add(vid)
            src = defs.get(vid)
            if src is None:
                continue
            prims.add(src.primitive.name)
            next_frontier.extend(src.invars)
        frontier = next_frontier
    return prims


def _defs_map(jaxpr: Any) -> dict:
    defs: dict = {}
    for eqn in jaxpr.eqns:
        for out in eqn.outvars:
            defs[id(out)] = eqn
    return defs


def audit_closed_jaxpr(
    closed: Any,
    label: str,
    *,
    strict: bool = True,
) -> list[Finding]:
    """Walk one traced entry point; return contract violations.

    ``strict=True``: flag every 16-bit accumulation / scan carry / exp-log,
    at any level.  ``strict=False`` (pure half policies): kernel interiors
    stay strict; engine-level 16-bit exp/log passes only when
    stability-mediated (max-subtraction reachable upstream).
    """
    findings: list[Finding] = []
    path = f"<jaxpr:{label}>"
    defs_cache: dict[int, dict] = {}

    def add(rule: str, message: str) -> None:
        findings.append(Finding(rule=rule, path=path, line=0, message=message))

    def visit(eqn: Any, jaxpr: Any, in_kernel: bool) -> None:
        name = eqn.primitive.name
        where = "pallas kernel body" if in_kernel else "engine level"
        if name in _ACCUM_PRIMS:
            half_out = [o.aval for o in eqn.outvars if _is_half(o.aval)]
            if half_out and (strict or in_kernel):
                add(
                    "jaxpr-half-accum",
                    f"{name} accumulates at {half_out[0].dtype.name} "
                    f"({where}) — reductions must carry fp32 under this "
                    "policy",
                )
        elif name == "scan":
            nc = eqn.params.get("num_consts", 0)
            ncar = eqn.params.get("num_carry", 0)
            for v in eqn.invars[nc : nc + ncar]:
                if _is_half(v.aval) and (strict or in_kernel):
                    add(
                        "jaxpr-half-accum",
                        f"scan carry of dtype {v.aval.dtype.name} ({where}) "
                        "— loop carries must accumulate fp32 under this "
                        "policy",
                    )
        elif name in _EXPLOG_PRIMS:
            half = any(_is_half(v.aval) for v in eqn.invars) or any(
                _is_half(o.aval) for o in eqn.outvars
            )
            if not half:
                return
            if strict or in_kernel:
                add(
                    "jaxpr-half-explog",
                    f"16-bit {name} ({where}) — transcendentals must run "
                    "fp32 under this policy",
                )
                return
            jid = id(jaxpr)
            if jid not in defs_cache:
                defs_cache[jid] = _defs_map(jaxpr)
            reach = _reachable_prims(eqn, defs_cache[jid])
            if "sub" not in reach or not (reach & _MEDIATORS):
                add(
                    "jaxpr-half-explog",
                    f"unmediated 16-bit {name} ({where}) — no "
                    "max-subtraction upstream; route through "
                    "repro.core.stability (logsumexp / stable weighting)",
                )

    _walk(closed.jaxpr, visit)
    return findings


# ---------------------------------------------------------------------------
# entry-point tracing


def _tracker_parts(policy_name: str, backend: str):
    from repro.core.precision import get_policy
    from repro.core.tracking import TrackerConfig, make_tracker_spec

    pol = get_policy(policy_name)
    cfg = TrackerConfig(
        num_particles=_P, height=_H, width=_W, backend=backend
    )
    return pol, cfg, make_tracker_spec


def _frame():
    return jnp.zeros((_H, _W), jnp.float32)


def trace_step(policy_name: str, backend: str):
    from repro.core.engine import FilterConfig, ParticleFilter

    pol, cfg, make_spec = _tracker_parts(policy_name, backend)
    flt = ParticleFilter(
        make_spec(cfg, pol),
        FilterConfig(policy=pol, backend=backend),
    )
    state = flt.init(jax.random.key(0), _P)
    return jax.make_jaxpr(flt.step)(state, _frame(), jax.random.key(1))


def _bank(policy_name: str, backend: str, slots: int = 2):
    from repro.core.engine import FilterBank, FilterConfig

    pol, cfg, make_spec = _tracker_parts(policy_name, backend)
    starts = jnp.asarray([[8.0, 8.0], [24.0, 24.0]][:slots])
    spec = make_spec(cfg, pol, starts=starts)
    return FilterBank(
        spec, FilterConfig(policy=pol, backend=backend), num_slots=slots
    )


def trace_bank_step(policy_name: str, backend: str, ragged: bool):
    bank = _bank(policy_name, backend)
    n_active = (
        jnp.asarray([_P, _P // 2], jnp.int32) if ragged else None
    )
    state = bank.init(jax.random.key(0), _P, n_active=n_active)
    keys = jax.random.split(jax.random.key(1), bank.num_slots)

    def step(s, obs, ks):
        return bank.step(s, obs, ks, shared_obs=True)

    return jax.make_jaxpr(step)(state, _frame(), keys)


def trace_resize_slot(policy_name: str, backend: str):
    bank = _bank(policy_name, backend)
    state = bank.init(
        jax.random.key(0), _P, n_active=jnp.asarray([_P, _P], jnp.int32)
    )
    return jax.make_jaxpr(bank.resize_slot)(
        state, jnp.int32(0), jax.random.key(1), jnp.int32(_P // 4)
    )


_ENTRY_POINTS = {
    "step": lambda p, b: trace_step(p, b),
    "bank_step": lambda p, b: trace_bank_step(p, b, ragged=False),
    "bank_step_masked": lambda p, b: trace_bank_step(p, b, ragged=True),
    "resize_slot": lambda p, b: trace_resize_slot(p, b),
}


def audit_dtypes(
    backends=("jnp", "pallas"),
    strict_policies=STRICT_POLICIES,
    kernel_only_policies=KERNEL_ONLY_POLICIES,
) -> tuple[list[Finding], list[str]]:
    """Trace every (entry point, backend, policy) cell; return findings plus
    a human log of what was audited or skipped."""
    findings: list[Finding] = []
    log: list[str] = []
    plan = [(p, True) for p in strict_policies] + [
        (p, False) for p in kernel_only_policies
    ]
    for backend in backends:
        for policy_name, strict in plan:
            if not strict and backend == "jnp":
                log.append(
                    f"skip {backend}/{policy_name}: pure half on the "
                    "reference backend accumulates in accum_dtype by "
                    "design (paper-faithful)"
                )
                continue
            for entry, tracer in _ENTRY_POINTS.items():
                label = f"{entry}:{backend}:{policy_name}"
                try:
                    closed = tracer(policy_name, backend)
                except Exception as e:  # surface, don't crash the audit
                    findings.append(
                        Finding(
                            rule="jaxpr-trace-error",
                            path=f"<jaxpr:{label}>",
                            line=0,
                            message=f"tracing failed: {type(e).__name__}: "
                            f"{e}",
                        )
                    )
                    continue
                got = audit_closed_jaxpr(closed, label, strict=strict)
                findings.extend(got)
                mode = "strict" if strict else "kernel-strict/mediated"
                log.append(f"audit {label} [{mode}]: {len(got)} finding(s)")
    return findings, log


# ---------------------------------------------------------------------------
# compiled-artifact checks


def _state_bytes(state) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(state)
        if hasattr(x, "dtype")
    )


def audit_donation() -> tuple[list[Finding], list[str]]:
    """Compile-level proof that donated entry points alias state in place."""
    findings: list[Finding] = []
    log: list[str] = []
    bank = _bank("fp32", "jnp")
    keys = jax.random.split(jax.random.key(1), bank.num_slots)
    state = bank.init(jax.random.key(0), _P)
    sb = _state_bytes(state)

    def check(label, plain_alias, donated_alias):
        log.append(
            f"donation {label}: plain={plain_alias}B "
            f"donated={donated_alias}B state={sb}B"
        )
        if plain_alias != 0:
            findings.append(
                Finding(
                    rule="jaxpr-donation",
                    path=f"<compile:{label}>",
                    line=0,
                    message=f"undonated entry aliases {plain_alias} bytes "
                    "— buffer reuse without donate_argnums is a jit "
                    "contract change",
                )
            )
        if donated_alias < 0.9 * sb:
            findings.append(
                Finding(
                    rule="jaxpr-donation",
                    path=f"<compile:{label}>",
                    line=0,
                    message=f"donated entry aliases only {donated_alias} "
                    f"of {sb} state bytes (<90%) — something is pinning "
                    "the state buffers (PR-5 class: an escaped view or a "
                    "copy inserted before the donated call)",
                )
            )

    try:
        plain = bank.jit_step_shared.lower(state, _frame(), keys).compile()
        donated = bank.jit_step_shared_donated.lower(
            state, _frame(), keys
        ).compile()
        check(
            "bank.step_shared",
            plain.memory_analysis().alias_size_in_bytes,
            donated.memory_analysis().alias_size_in_bytes,
        )
    except Exception as e:
        findings.append(
            Finding(
                rule="jaxpr-trace-error",
                path="<compile:bank.step_shared>",
                line=0,
                message=f"donation audit failed: {type(e).__name__}: {e}",
            )
        )

    try:
        rbank = _bank("fp32", "jnp")
        rstate = rbank.init(
            jax.random.key(0), _P, n_active=jnp.asarray([_P, _P], jnp.int32)
        )
        rsb = _state_bytes(rstate)
        args = (rstate, jnp.int32(0), jax.random.key(1), jnp.int32(16))
        rdon = rbank.jit_resize_slot_donated.lower(*args).compile()
        alias = rdon.memory_analysis().alias_size_in_bytes
        log.append(f"donation bank.resize_slot: donated={alias}B state={rsb}B")
        if alias < 0.9 * rsb:
            findings.append(
                Finding(
                    rule="jaxpr-donation",
                    path="<compile:bank.resize_slot>",
                    line=0,
                    message=f"donated resize_slot aliases only {alias} of "
                    f"{rsb} state bytes (<90%)",
                )
            )
    except Exception as e:
        findings.append(
            Finding(
                rule="jaxpr-trace-error",
                path="<compile:bank.resize_slot>",
                line=0,
                message=f"donation audit failed: {type(e).__name__}: {e}",
            )
        )
    return findings, log


def audit_recompile() -> tuple[list[Finding], list[str]]:
    """Budget transitions must stay traced: N distinct (slot, count) pairs,
    one cache entry."""
    findings: list[Finding] = []
    log: list[str] = []
    try:
        bank = _bank("fp32", "jnp")
        state = bank.init(
            jax.random.key(0), _P, n_active=jnp.asarray([_P, _P], jnp.int32)
        )
        transitions = [(0, 16), (1, 8), (0, _P), (1, 32)]
        for i, (slot, k) in enumerate(transitions):
            state = bank.jit_resize_slot(
                state,
                jnp.int32(slot),
                jax.random.fold_in(jax.random.key(7), i),
                jnp.int32(k),
            )
            n = bank.jit_resize_slot._cache_size()
            if n != 1:
                findings.append(
                    Finding(
                        rule="jaxpr-recompile",
                        path="<compile:bank.resize_slot>",
                        line=0,
                        message=f"budget transition {(slot, k)} recompiled "
                        f"(cache size {n}) — slot/count must stay traced "
                        "(the elastic controller's no-recompile contract)",
                    )
                )
                break
        else:
            log.append(
                f"recompile bank.resize_slot: {len(transitions)} "
                "transitions, 1 cache entry"
            )
    except Exception as e:
        findings.append(
            Finding(
                rule="jaxpr-trace-error",
                path="<compile:bank.resize_slot>",
                line=0,
                message=f"recompile probe failed: {type(e).__name__}: {e}",
            )
        )
    return findings, log


def run_audit(
    backends=("jnp", "pallas"),
) -> tuple[list[Finding], list[str]]:
    """The full Engine-2 pass: dtype contracts, donation, recompile."""
    findings, log = audit_dtypes(backends=backends)
    for part in (audit_donation, audit_recompile):
        f, l = part()
        findings.extend(f)
        log.extend(l)
    return findings, log
