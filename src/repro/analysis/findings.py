"""Finding / pragma / baseline plumbing shared by both analysis engines.

A :class:`Finding` is one contract violation.  Its *fingerprint* hashes the
rule name, the repo-relative path, and the normalized source line (or
message, for non-source findings like registry or jaxpr audits) — NOT the
line number — so a finding survives unrelated edits above it and the
baseline does not churn on every diff.

Two suppression channels:

- **Pragma** — an inline ``# analysis: allow(<rule>): <why>`` comment on the
  flagged line (or the line directly above it).  For violations that are
  *intentional and local* (a pure-jnp oracle that must stay independent of
  the kernel bodies, a documented dtype choice).  The justification text is
  required: a bare ``allow`` with no reason is itself reported.
- **Baseline** — ``analysis/baseline.json``, a list of fingerprints for
  known findings the pass was landed green against.  CI gates on *new*
  findings only; ``--write-baseline`` refreshes the file.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re

__all__ = [
    "Finding",
    "PRAGMA_RE",
    "baseline_path",
    "load_baseline",
    "write_baseline",
    "parse_pragmas",
    "apply_pragmas",
    "split_baseline",
]

PRAGMA_RE = re.compile(
    r"#\s*analysis:\s*allow\(([a-z0-9_-]+)\)\s*:?\s*(.*)$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation from either engine."""

    rule: str
    path: str  # repo-relative source path, or a "<jaxpr:...>" pseudo-path
    line: int  # 1-based; 0 for non-source findings
    message: str
    snippet: str = ""  # the flagged source line, stripped

    @property
    def fingerprint(self) -> str:
        basis = "|".join(
            (self.rule, self.path, self.snippet or self.message)
        )
        return hashlib.sha1(basis.encode()).hexdigest()[:16]

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


def baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str | None = None) -> set[str]:
    path = path or baseline_path()
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return {entry["fingerprint"] for entry in data.get("findings", [])}


def write_baseline(findings: list[Finding], path: str | None = None) -> str:
    path = path or baseline_path()
    payload = {
        "version": 1,
        "note": (
            "Known findings the analysis pass was landed green against; "
            "`python -m repro.analysis --check` fails only on findings NOT "
            "listed here.  Refresh with --write-baseline; prefer fixing or "
            "pragma-ing findings over baselining them."
        ),
        "findings": [
            {
                "fingerprint": f.fingerprint,
                "rule": f.rule,
                "path": f.path,
                "message": f.message,
            }
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def parse_pragmas(source: str) -> tuple[dict[int, set[str]], list[int]]:
    """Map line number -> set of allowed rule names, plus the line numbers
    of malformed pragmas (no justification text)."""
    allowed: dict[int, set[str]] = {}
    bare: list[int] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        rule, why = m.group(1), m.group(2).strip()
        if not why:
            bare.append(i)
            continue
        allowed.setdefault(i, set()).add(rule)
    return allowed, bare


def apply_pragmas(
    findings: list[Finding], source: str, path: str
) -> list[Finding]:
    """Drop findings covered by a pragma on their own line or anywhere in
    the contiguous comment block directly above it (pragma justifications
    routinely wrap to two comment lines); report malformed
    (justification-free) pragmas as findings themselves."""
    allowed, bare = parse_pragmas(source)
    lines = source.splitlines()

    def covering(line: int) -> set[str]:
        rules = set(allowed.get(line, set()))
        i = line - 1
        while 1 <= i <= len(lines) and lines[i - 1].lstrip().startswith("#"):
            rules |= allowed.get(i, set())
            i -= 1
        return rules

    out = []
    for f in findings:
        if f.rule in covering(f.line):
            continue
        out.append(f)
    for ln in bare:
        out.append(
            Finding(
                rule="pragma",
                path=path,
                line=ln,
                message=(
                    "analysis pragma without a justification — write "
                    "`# analysis: allow(<rule>): <one-line reason>`"
                ),
                snippet=lines[ln - 1].strip() if ln <= len(lines) else "",
            )
        )
    return out


def split_baseline(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    """(new, suppressed) partition against the baseline fingerprints."""
    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]
    return new, old
