"""SMC model/state types + legacy shims for the particle-filter engine.

The filter itself lives in :mod:`repro.core.engine`: a
:class:`~repro.core.engine.ParticleFilter` built from a
:class:`~repro.core.engine.FilterConfig` (precision policy, backend,
resampler, ESS threshold, mesh distribution spec — all registry names)
exposes ``init`` / ``step`` / ``run`` / ``stream``.  This module keeps the
pieces that describe the *model* rather than the execution:

- :class:`SMCSpec` — the model as three callables (init, transition,
  log-likelihood) over a pytree of per-particle arrays, so the same engine
  drives the paper's object tracker (``repro.core.tracking``), the
  distributed filter (``repro.core.distributed``) and SMC decoding of
  language models (``repro.launch.serve --smc``).
- :class:`FilterState` / :class:`FilterOutput` — the carried state and the
  per-frame outputs (estimate, ESS, evidence increment, resample flag, max
  log-likelihood — the paper's six-kernel chain observables, Fig. 1).

``pf_init`` / ``pf_step`` / ``pf_scan`` are deprecation shims kept for old
call sites; each warns once and forwards to an equivalent engine call
(bit-identical results — the engine's jnp backend *is* the old code path).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple

import jax

from repro.core.precision import PrecisionPolicy

__all__ = [
    "SMCSpec",
    "FilterState",
    "FilterOutput",
    "pf_init",
    "pf_step",
    "pf_scan",
]


@dataclasses.dataclass(frozen=True)
class SMCSpec:
    """A sequential Monte Carlo model.

    init:       (key, num_particles) -> particles pytree, leading dim P
    transition: (key, particles, step) -> particles          (propagation)
    loglik:     (particles, observation, step) -> (P,) log-likelihoods

    Optional hooks for exotic state (e.g. LM caches in SMC decoding):

    gather:     (particles, ancestors) -> particles — ancestor selection for
                pytrees whose particle axis is not leading everywhere;
                defaults to ``resampling.gather_ancestors`` (axis 0).
    summary:    (particles, weights) -> estimate pytree — replaces the
                default weighted posterior mean (weights in accum dtype);
                use when averaging the full state is meaningless or costly.
    slot_init:  (key, num_particles, slot) -> particles — banked per-slot
                initialization for :class:`repro.core.engine.FilterBank`;
                ``slot`` is the (possibly traced) int32 slot index, letting a
                shared spec start each slot differently (e.g. per-target
                start positions in multi-object tracking).  Falls back to
                ``init`` when None; ignored by ``ParticleFilter``.
    particle_axes: pytree of ints matching ``particles`` — each leaf's
                particle-axis position, for pytrees whose particle axis is
                not leading everywhere (LM caches).  Mesh distribution uses
                it to shard, all-gather, and ring-exchange each leaf along
                the right dimension; None means axis 0 everywhere.  Specs
                setting it should also set ``gather`` (same layout
                knowledge) and, under a meshed bank, ``summary``.
    """

    init: Callable[..., Any]
    transition: Callable[..., Any]
    loglik: Callable[..., jax.Array]
    gather: Callable[..., Any] | None = None
    summary: Callable[..., Any] | None = None
    slot_init: Callable[..., Any] | None = None
    particle_axes: Any = None


class FilterState(NamedTuple):
    particles: Any
    log_weights: jax.Array  # (P,) unnormalized, compute dtype
    step: jax.Array  # int32 scalar


class FilterOutput(NamedTuple):
    estimate: Any  # weighted posterior mean, same structure as particles
    ess: jax.Array  # effective sample size
    log_z_inc: jax.Array  # per-step log evidence increment
    resampled: jax.Array  # bool: did this step resample
    max_loglik: jax.Array  # for diagnostics / paper's max kernel parity


_WARNED: set[str] = set()


def _warn_once(old: str, new: str) -> None:
    """Warn-once helper shared by every legacy shim (here and tracking)."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated; use {new} (see repro.core.engine)",
        DeprecationWarning,
        stacklevel=3,
    )


def _engine(spec, policy, *, resampler, ess_threshold, backend):
    from repro.core.engine import FilterConfig, ParticleFilter

    return ParticleFilter(
        spec,
        FilterConfig(
            policy=policy,
            backend=backend,
            resampler=resampler,
            ess_threshold=ess_threshold,
        ),
    )


def pf_init(
    spec: SMCSpec, policy: PrecisionPolicy, key: jax.Array, num_particles: int
) -> FilterState:
    """Deprecated: use ``ParticleFilter(spec, config).init(key, P)``."""
    _warn_once("repro.core.filter.pf_init", "ParticleFilter.init")
    from repro.core.engine import FilterConfig, ParticleFilter

    return ParticleFilter(spec, FilterConfig(policy=policy)).init(
        key, num_particles
    )


def pf_step(
    spec: SMCSpec,
    policy: PrecisionPolicy,
    state: FilterState,
    observation: Any,
    key: jax.Array,
    *,
    resampler: str = "systematic",
    ess_threshold: float = 1.0,
    backend: str = "jnp",
) -> tuple[FilterState, FilterOutput]:
    """Deprecated: use ``ParticleFilter(spec, config).step(state, obs, key)``."""
    _warn_once("repro.core.filter.pf_step", "ParticleFilter.step")
    return _engine(
        spec,
        policy,
        resampler=resampler,
        ess_threshold=ess_threshold,
        backend=backend,
    ).step(state, observation, key)


def pf_scan(
    spec: SMCSpec,
    policy: PrecisionPolicy,
    key: jax.Array,
    observations: Any,
    num_particles: int,
    *,
    resampler: str = "systematic",
    ess_threshold: float = 1.0,
    backend: str = "jnp",
) -> tuple[FilterState, FilterOutput]:
    """Deprecated: use ``ParticleFilter(spec, config).run(key, obs, P)``."""
    _warn_once("repro.core.filter.pf_scan", "ParticleFilter.run")
    return _engine(
        spec,
        policy,
        resampler=resampler,
        ess_threshold=ess_threshold,
        backend=backend,
    ).run(key, observations, num_particles)
