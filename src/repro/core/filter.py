"""The particle filter core: propagate → weight → (normalize) → resample.

Generic over the state space: a model is three callables (init, transition,
log-likelihood) over a pytree of per-particle arrays, so the same machinery
drives the paper's object tracker (``repro.core.tracking``), the distributed
filter (``repro.core.distributed``) and SMC decoding of language models
(``examples/smc_decode.py``).

Faithful to the paper's per-frame kernel chain:

    propagation → likelihood → max-finding → weighting → normalizing →
    resampling                                  (paper Fig. 1, six kernels)

With ``backend="pallas"`` the max-finding + weighting + normalizing chain is
replaced by the fused one-pass online-LSE kernel and the CDF build by the
carry-cumsum kernel (``repro.kernels``) — the TPU-native restructuring; with
``backend="jnp"`` the pure-jnp reference forms are used.  Both are bit-tested
against each other.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import resampling, stability
from repro.core.precision import PrecisionPolicy

__all__ = ["SMCSpec", "FilterState", "FilterOutput", "pf_step", "pf_scan"]


@dataclasses.dataclass(frozen=True)
class SMCSpec:
    """A sequential Monte Carlo model.

    init:       (key, num_particles) -> particles pytree, leading dim P
    transition: (key, particles, step) -> particles          (propagation)
    loglik:     (particles, observation, step) -> (P,) log-likelihoods
    """

    init: Callable[..., Any]
    transition: Callable[..., Any]
    loglik: Callable[..., jax.Array]


class FilterState(NamedTuple):
    particles: Any
    log_weights: jax.Array  # (P,) unnormalized, compute dtype
    step: jax.Array  # int32 scalar


class FilterOutput(NamedTuple):
    estimate: Any  # weighted posterior mean, same structure as particles
    ess: jax.Array  # effective sample size
    log_z_inc: jax.Array  # per-step log evidence increment
    resampled: jax.Array  # bool: did this step resample
    max_loglik: jax.Array  # for diagnostics / paper's max kernel parity


def _weighted_mean(particles, weights, adt):
    # Scale-invariant: divide by the *actual* weight sum.  In 16-bit,
    # exp(log_w - lse) does not sum to 1 (bf16 resolves log-weights ~300
    # only to ±2, i.e. a factor e^2 on each weight) — trusting the LSE to
    # normalize inflates the estimate off the image.  Lesson recorded in
    # EXPERIMENTS.md §Paper-validation.
    w = weights.astype(adt)
    total = jnp.sum(w)

    def _mean(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x  # integer states (e.g. token ids) are not averaged
        wx = w.reshape(w.shape + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(adt) * wx, axis=0) / total

    return jax.tree.map(_mean, particles)


def _normalize(log_w, policy: PrecisionPolicy, backend: str):
    """(normalized weights, log_z, max log-weight) per the active policy."""
    if not policy.stable_weighting:
        # Paper's naive path: direct exponentiation, overflow and all.
        w, log_z = stability.normalize_log_weights(log_w, stable=False)
        return w, log_z, jnp.max(log_w)
    if backend == "pallas":
        from repro.kernels.logsumexp import ops as lse_ops

        w, m, lse = lse_ops.normalize_weights(log_w)
        return w, lse, m
    m = jnp.max(log_w)
    lse = stability.logsumexp(log_w.astype(policy.accum_dtype), axis=-1)
    w = jnp.exp(log_w.astype(policy.accum_dtype) - lse).astype(log_w.dtype)
    return w, lse, m


def pf_step(
    spec: SMCSpec,
    policy: PrecisionPolicy,
    state: FilterState,
    observation: Any,
    key: jax.Array,
    *,
    resampler: str = "systematic",
    ess_threshold: float = 1.0,
    backend: str = "jnp",
) -> tuple[FilterState, FilterOutput]:
    """One frame of the filter.

    ess_threshold: resample when ESS < threshold * P.  1.0 (default)
    resamples every step, matching Rodinia/the paper; <1.0 is adaptive.
    """
    cdt = policy.compute_dtype
    k_prop, k_res = jax.random.split(key)
    num_particles = state.log_weights.shape[0]

    # 1. propagation (paper kernel 1)
    particles = spec.transition(k_prop, state.particles, state.step)

    # 2. likelihood (kernel 2)
    log_lik = spec.loglik(particles, observation, state.step).astype(cdt)
    log_w = state.log_weights + log_lik

    # 3-5. max-find + weighting + normalizing (kernels 3-5; fused for pallas)
    weights, log_z, max_lw = _normalize(log_w, policy, backend)
    prev_lse = stability.logsumexp(
        state.log_weights.astype(policy.accum_dtype), axis=-1
    )
    log_z_inc = log_z - prev_lse
    ess = stability.effective_sample_size(weights.astype(policy.accum_dtype))

    estimate = _weighted_mean(particles, weights, policy.accum_dtype)

    # 6. resampling (kernel 6)
    do_resample = ess < ess_threshold * num_particles + 0.5  # ==1.0 -> always
    resample_fn = resampling.make_resampler(resampler)

    def _resampled():
        if backend == "pallas" and resampler == "systematic":
            from repro.kernels.resample import ops as res_ops

            ancestors = res_ops.systematic_resample(k_res, weights)
        else:
            ancestors = resample_fn(k_res, weights, policy)
        new_particles = resampling.gather_ancestors(particles, ancestors)
        uniform = jnp.full_like(log_w, -jnp.log(float(num_particles)))
        return new_particles, uniform

    def _kept():
        return particles, jnp.log(weights.astype(policy.accum_dtype)).astype(
            log_w.dtype
        )

    new_particles, new_log_w = jax.lax.cond(do_resample, _resampled, _kept)

    new_state = FilterState(
        particles=new_particles,
        log_weights=new_log_w,
        step=state.step + 1,
    )
    out = FilterOutput(
        estimate=estimate,
        ess=ess,
        log_z_inc=log_z_inc,
        resampled=do_resample,
        max_loglik=max_lw,
    )
    return new_state, out


def pf_init(
    spec: SMCSpec, policy: PrecisionPolicy, key: jax.Array, num_particles: int
) -> FilterState:
    particles = spec.init(key, num_particles)
    particles = jax.tree.map(
        lambda x: x.astype(policy.compute_dtype)
        if jnp.issubdtype(x.dtype, jnp.inexact)
        else x,
        particles,
    )
    log_w = jnp.full(
        (num_particles,), -jnp.log(float(num_particles)), policy.compute_dtype
    )
    return FilterState(particles, log_w, jnp.asarray(0, jnp.int32))


def pf_scan(
    spec: SMCSpec,
    policy: PrecisionPolicy,
    key: jax.Array,
    observations: Any,
    num_particles: int,
    *,
    resampler: str = "systematic",
    ess_threshold: float = 1.0,
    backend: str = "jnp",
) -> tuple[FilterState, FilterOutput]:
    """Run the filter over a sequence of observations with ``lax.scan``.

    observations: pytree with a leading time axis (e.g. video (T, H, W)).
    Returns (final state, stacked per-step outputs).
    """
    k_init, k_run = jax.random.split(key)
    state0 = pf_init(spec, policy, k_init, num_particles)
    num_steps = jax.tree.leaves(observations)[0].shape[0]
    step_keys = jax.random.split(k_run, num_steps)

    def body(state, xs):
        obs, k = xs
        return pf_step(
            spec,
            policy,
            state,
            obs,
            k,
            resampler=resampler,
            ess_threshold=ess_threshold,
            backend=backend,
        )

    return jax.lax.scan(body, state0, (observations, step_keys))
