"""SMC model/state types for the particle-filter engine.

The filter itself lives in :mod:`repro.core.engine`: a
:class:`~repro.core.engine.ParticleFilter` built from a
:class:`~repro.core.engine.FilterConfig` (precision policy, backend,
resampler, ESS threshold, mesh distribution spec — all registry names)
exposes ``init`` / ``step`` / ``run`` / ``stream``.  This module keeps the
pieces that describe the *model* rather than the execution:

- :class:`SMCSpec` — the model as three callables (init, transition,
  log-likelihood) over a pytree of per-particle arrays, so the same engine
  drives the paper's object tracker (``repro.core.tracking``), the
  distributed filter (``repro.core.distributed``) and SMC decoding of
  language models (``repro.launch.serve --smc``).
- :class:`FilterState` / :class:`FilterOutput` — the carried state and the
  per-frame outputs (estimate, ESS, evidence increment, resample flag, max
  log-likelihood — the paper's six-kernel chain observables, Fig. 1).

The long-deprecated ``pf_init`` / ``pf_step`` / ``pf_scan`` shims (and
``repro.core.tracking.track``) are gone: every caller goes through the
engine API now.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax

__all__ = [
    "SMCSpec",
    "StepFusion",
    "FilterState",
    "FilterOutput",
]


@dataclasses.dataclass(frozen=True)
class StepFusion:
    """Opt-in description of a fusable likelihood for the full-step kernel.

    A spec whose ``loglik`` is "gather observation patches, score them with
    the intensity model" can expose that structure here, letting the engine
    fuse likelihood → weights → resample into one streaming pass
    (``Backend.fused_step``) instead of materializing the (B, P) log-weight
    array between kernels.

    gather:  (particles, observation, step) -> (P, J) patches — the
             observation-gather half of ``loglik``.  The engine calls it in
             place of ``loglik`` on the fused path; ``loglik`` itself must
             equal "score ``gather``'s patches with ``model``" for the
             fused and composed paths to agree.
    model:   the intensity model (``repro.core.likelihood.IntensityModel``)
             the backend's fused step kernel scores patches with.
    backend: optional backend-name gate — fuse only when
             ``FilterConfig.backend`` matches (the tracker sets this so a
             jnp-configured filter keeps its composed jnp chain).  None
             fuses on any backend that registers a ``fused_step`` form.
    """

    gather: Callable[..., Any]
    model: Any
    backend: str | None = None


@dataclasses.dataclass(frozen=True)
class SMCSpec:
    """A sequential Monte Carlo model.

    init:       (key, num_particles) -> particles pytree, leading dim P
    transition: (key, particles, step) -> particles          (propagation)
    loglik:     (particles, observation, step) -> (P,) log-likelihoods

    Optional hooks for exotic state (e.g. LM caches in SMC decoding):

    gather:     (particles, ancestors) -> particles — ancestor selection for
                pytrees whose particle axis is not leading everywhere;
                defaults to ``resampling.gather_ancestors`` (axis 0).
    summary:    (particles, weights) -> estimate pytree — replaces the
                default weighted posterior mean (weights in accum dtype);
                use when averaging the full state is meaningless or costly.
    slot_init:  (key, num_particles, slot) -> particles — banked per-slot
                initialization for :class:`repro.core.engine.FilterBank`;
                ``slot`` is the (possibly traced) int32 slot index, letting a
                shared spec start each slot differently (e.g. per-target
                start positions in multi-object tracking).  Falls back to
                ``init`` when None; ignored by ``ParticleFilter``.
    particle_axes: pytree of ints matching ``particles`` — each leaf's
                particle-axis position, for pytrees whose particle axis is
                not leading everywhere (LM caches).  Mesh distribution uses
                it to shard, all-gather, and ring-exchange each leaf along
                the right dimension; None means axis 0 everywhere.  Specs
                setting it should also set ``gather`` (same layout
                knowledge) and, under a meshed bank, ``summary``.
    step_fusion: optional :class:`StepFusion` splitting ``loglik`` into
                gather + intensity model so the engine can run the fused
                full-step kernel (likelihood → weights → resample in one
                pass, gated by ``FilterConfig.fused_step``).  ``loglik``
                stays authoritative: it is what every composed path runs,
                and the fused path must be bitwise equal to it.
    """

    init: Callable[..., Any]
    transition: Callable[..., Any]
    loglik: Callable[..., jax.Array]
    gather: Callable[..., Any] | None = None
    summary: Callable[..., Any] | None = None
    slot_init: Callable[..., Any] | None = None
    particle_axes: Any = None
    step_fusion: Any = None


class FilterState(NamedTuple):
    """Carried filter state; a bank adds a leading slot axis per leaf.

    The two trailing fields exist only on *ragged* banks (per-slot active
    particle counts — see ``FilterBank.init(..., n_active=...)``); they are
    ``None`` on dense banks and single filters, so the dense pytree
    structure is unchanged.

    n_active:    (B,) int32 per-slot active lane counts (lanes >= n_active
                 are padding: log-weight -inf, weight exactly 0).
    log_uniform: (B,) compute-dtype ``-log(n_active)`` — the uniform
                 log-weight each slot resets to after resampling.  Stored
                 (not recomputed per step) so every reset in a slot's
                 lifetime uses bit-identical values: ``log`` is
                 transcendental, and XLA's constant-folded log differs from
                 its runtime vectorized log by 1 ulp for some counts.
    """

    particles: Any
    log_weights: jax.Array  # (P,) unnormalized, compute dtype
    step: jax.Array  # int32 scalar
    n_active: Any = None
    log_uniform: Any = None


class FilterOutput(NamedTuple):
    estimate: Any  # weighted posterior mean, same structure as particles
    ess: jax.Array  # effective sample size
    log_z_inc: jax.Array  # per-step log evidence increment
    resampled: jax.Array  # bool: did this step resample
    max_loglik: jax.Array  # for diagnostics / paper's max kernel parity
