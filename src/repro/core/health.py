"""Per-slot health sentinels for long-lived serving banks.

The paper's thesis is that half-precision particle filters pay off *only*
when their numerical failure modes are actively mitigated — its
algorithmic changes are recovery mechanisms for fp16 collapse.  At
serving scale the same failures show up operationally: one non-finite
weight row, a collapsed posterior, or a hung bank step in a donated
in-place serve loop either propagates silently or kills the whole run.
This module is the *detection* half of the fault-tolerance layer
(``repro.core.faults`` is the reproducibility half; the escalation
ladder lives in ``repro.launch.serve``).

Every health rule is derived from numbers the scheduler already holds —
**zero extra device passes**:

- ``nonfinite``   — the slot's per-step ESS or log-evidence increment is
  NaN/±Inf (the fused epilogue's ``sum_w``/``sum_w2``/``max_log_w``/
  ``log_z`` stats surface any non-finite weight lane as a non-finite
  ESS or ``log_z_inc``, so poisoned particle state is visible the first
  step after it happens), or its max log-likelihood is NaN.
- ``divergence``  — the per-step evidence increment stays below a
  catastrophic floor for ``divergence_after`` consecutive ticks: the
  observation likelihood says the whole cloud is nowhere near the
  target (distinct from a *collapsed* cloud, whose ESS pins at ~1 while
  its evidence may look fine).
- ``collapse``    — ESS pinned under ``collapse_below`` for
  ``collapse_after`` consecutive ticks.  This overlaps the elastic
  controller's grow/reseed escalation by design; the serving scheduler
  enables this rule only when no :class:`~repro.core.elastic.
  BudgetController` is driving the slot (otherwise two loops would
  fight over the same signal).
- ``stuck``       — progress integrity: a busy slot's device step
  counter (already read back every tick for the retire scan) does not
  match the steps the scheduler has dispatched since admission.  This
  is how a *dropped slot upload* (admission bookkeeping without the
  device write) or a silently skipped step surfaces without any
  dedicated probe.
- the **step watchdog** — wall-clock: a bank step whose
  dispatch→consumption latency exceeds ``step_timeout_ms`` trips the
  lane (async scheduling makes a hung device step otherwise invisible:
  the host happily keeps dispatching).

Recovery bookkeeping: the scheduler reports each applied escalation
action (:meth:`slot_action`), and the monitor closes the incident the
first tick the slot reads healthy again — ``stats["recoveries"]``
carries per-incident trip/recovery ticks and the action that cleared
it, which ``benchmarks/chaos.py`` turns into recovery rate + latency
per injected fault class.

Process-wide counters (:func:`health_counters`) mirror every trip and
recovery so ``benchmarks.common.write_bench_json`` can stamp the health
state of the run into every ``BENCH_*.json``.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = [
    "HealthConfig",
    "HealthEvent",
    "HealthMonitor",
    "health_counters",
    "reset_health_counters",
]

# Process-wide mirror of every monitor's trip/recovery counters, stamped
# into BENCH_*.json by benchmarks.common.write_bench_json: a bench number
# measured in a run that tripped health sentinels should say so.
_COUNTERS: collections.Counter = collections.Counter()


def health_counters() -> dict:
    """Snapshot of the process-wide health counters (trips + recoveries
    accumulated across every HealthMonitor in this process)."""
    return dict(_COUNTERS)


def reset_health_counters() -> None:
    _COUNTERS.clear()


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds of the per-slot health rules.

    Defaults are deliberately loose: on a healthy serve they must never
    fire (the no-fault serve path is required to be bitwise identical to
    a run without monitoring — a spurious trip would trigger recovery
    surgery and break that).

    collapse_below:   ESS under this is a collapsed posterior.  <= 0
                      disables the rule (the serving scheduler disables
                      it whenever an elastic BudgetController already
                      owns the collapse signal).
    collapse_after:   consecutive collapsed ticks before the trip.
    divergence_below: per-step log-evidence increments under this floor
                      are catastrophic (default -1e6: a genuinely
                      impossible observation, not a bad frame).
    divergence_after: consecutive such ticks before the trip.
    step_timeout_ms:  wall-clock watchdog on each bank step's
                      dispatch→consumption latency; None disables.
    snapshot_every:   cadence (ticks) of the scheduler's host-side
                      rollback snapshots (consumed by the serve loop,
                      carried here so one config travels).
    snapshot_depth:   ring depth per slot.
    max_step_retries: bounded backoff for failed/timed-out steps before
                      the failure escalates to the slot ladder.
    """

    collapse_below: float = 0.0
    collapse_after: int = 3
    divergence_below: float = -1e6
    divergence_after: int = 2
    step_timeout_ms: float | None = None
    snapshot_every: int = 4
    snapshot_depth: int = 2
    max_step_retries: int = 2

    def __post_init__(self) -> None:
        if self.collapse_after < 1:
            raise ValueError(
                f"collapse_after must be >= 1, got {self.collapse_after}"
            )
        if self.divergence_after < 1:
            raise ValueError(
                f"divergence_after must be >= 1, got {self.divergence_after}"
            )
        if self.step_timeout_ms is not None and self.step_timeout_ms <= 0:
            raise ValueError(
                f"step_timeout_ms must be > 0 (or None to disable), got "
                f"{self.step_timeout_ms}"
            )
        if self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {self.snapshot_every}"
            )
        if self.snapshot_depth < 1:
            raise ValueError(
                f"snapshot_depth must be >= 1, got {self.snapshot_depth}"
            )
        if self.max_step_retries < 0:
            raise ValueError(
                f"max_step_retries must be >= 0, got {self.max_step_retries}"
            )


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One health trip for one slot on one tick.

    ``kind`` is the rule that fired ("nonfinite" | "divergence" |
    "collapse" | "stuck").  The scheduler answers with an escalation
    action and reports it back via :meth:`HealthMonitor.slot_action`.
    """

    slot: int
    tick: int
    kind: str
    ess: float
    log_z_inc: float


class HealthMonitor:
    """Host-side per-slot health state machine.

    Call :meth:`observe` once per scheduler tick with that tick's
    already-materialized per-slot stats; it returns an alert for every
    unhealthy busy slot.  A slot with an open incident does not *re-trip*
    (trip counters count incidents, not unhealthy ticks) but it keeps
    alerting until healthy — the scheduler uses the incident's applied
    ``actions`` to pick the next escalation rung.  Report applied
    recovery actions via :meth:`slot_action`; the incident closes on the
    first healthy read afterward and lands in ``stats["recoveries"]``.
    ``slot_reset`` clears a slot's history on admission/retire.
    """

    def __init__(self, config: HealthConfig, num_slots: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.config = config
        self.num_slots = num_slots
        self._collapse = np.zeros(num_slots, np.int64)
        self._diverge = np.zeros(num_slots, np.int64)
        # Open incident per slot: {"tick", "kind", "actions": [...]}.
        self._pending: dict[int, dict] = {}
        self.trips: collections.Counter = collections.Counter()
        self.recoveries: collections.Counter = collections.Counter()
        self.events: list[dict] = []
        self.recovered: list[dict] = []
        self.watchdog_trips = 0
        self.step_retries = 0

    # -- per-tick observation -------------------------------------------

    def observe(
        self,
        tick: int,
        ess: np.ndarray,
        log_z_inc: np.ndarray,
        max_loglik: np.ndarray,
        busy: np.ndarray,
        expected_step: np.ndarray | None = None,
        observed_step: np.ndarray | None = None,
    ) -> list[HealthEvent]:
        """Evaluate every rule for every busy slot; return alerts (one
        per unhealthy busy slot — new trips and ongoing incidents both).

        ess / log_z_inc / max_loglik: (B,) per-slot stats from the step
        the scheduler just consumed (``FilterOutput`` — all derived from
        the fused epilogue's ``sum_w``/``sum_w2``/``max_log_w``/``log_z``
        row stats, so no extra device reads happen here).
        busy: (B,) bool — only busy slots are judged.
        expected_step / observed_step: optional (B,) int — steps the
        scheduler believes it has dispatched since admission vs. the
        device step counter it read back for the retire scan; any
        busy-slot mismatch is a ``stuck`` trip (dropped upload, skipped
        step).
        """
        cfg = self.config
        ess = np.asarray(ess, np.float64)
        log_z = np.asarray(log_z_inc, np.float64)
        mll = np.asarray(max_loglik, np.float64)
        busy = np.asarray(busy, bool)
        for name, arr in (("ess", ess), ("log_z_inc", log_z)):
            if arr.shape != (self.num_slots,):
                raise ValueError(
                    f"{name} must be shaped ({self.num_slots},), got "
                    f"{arr.shape}"
                )

        nonfinite = busy & (
            ~np.isfinite(ess) | ~np.isfinite(log_z) | np.isnan(mll)
        )

        if cfg.collapse_below > 0:
            collapsed = busy & (ess < cfg.collapse_below)
            self._collapse[collapsed] += 1
            self._collapse[~collapsed] = 0
            collapse_trip = self._collapse >= cfg.collapse_after
        else:
            collapse_trip = np.zeros(self.num_slots, bool)

        diverged = busy & np.isfinite(log_z) & (log_z < cfg.divergence_below)
        self._diverge[diverged] += 1
        self._diverge[~diverged] = 0
        diverge_trip = self._diverge >= cfg.divergence_after

        stuck = np.zeros(self.num_slots, bool)
        if expected_step is not None and observed_step is not None:
            stuck = busy & (
                np.asarray(expected_step, np.int64)
                != np.asarray(observed_step, np.int64)
            )

        alerts: list[HealthEvent] = []
        for slot in range(self.num_slots):
            if not busy[slot]:
                continue
            kind = None
            # Severity order: a non-finite slot is corrupted whatever
            # else its counters say; stuck (no valid state at all) next.
            if nonfinite[slot]:
                kind = "nonfinite"
            elif stuck[slot]:
                kind = "stuck"
            elif diverge_trip[slot]:
                kind = "divergence"
            elif collapse_trip[slot]:
                kind = "collapse"
            if kind is None:
                # Healthy read: close any open incident whose recovery
                # action has been applied.
                inc = self._pending.get(slot)
                if inc is not None and inc["actions"]:
                    self._close(slot, tick, inc)
                continue
            ev = HealthEvent(
                slot=slot,
                tick=tick,
                kind=kind,
                ess=float(ess[slot]),
                log_z_inc=float(log_z[slot]),
            )
            if slot not in self._pending:
                # New incident: count the trip.  An ongoing incident
                # keeps alerting (the ladder escalates) but counts once.
                self._pending[slot] = {
                    "tick": tick, "kind": kind, "actions": [],
                }
                self.trips[kind] += 1
                _COUNTERS[f"trips_{kind}"] += 1
                self.events.append(dataclasses.asdict(ev))
            alerts.append(ev)
        return alerts

    def _close(self, slot: int, tick: int, inc: dict) -> None:
        action = inc["actions"][-1]
        self.recoveries[action] += 1
        _COUNTERS[f"recoveries_{action}"] += 1
        self.recovered.append(
            {
                "slot": slot,
                "kind": inc["kind"],
                "trip_tick": inc["tick"],
                "recovered_tick": tick,
                "latency_ticks": tick - inc["tick"],
                "action": action,
                "actions": list(inc["actions"]),
            }
        )
        del self._pending[slot]

    # -- scheduler feedback ---------------------------------------------

    def pending(self, slot: int) -> dict | None:
        """The slot's open incident (None when healthy)."""
        return self._pending.get(slot)

    def slot_action(self, slot: int, action: str, tick: int = 0) -> None:
        """The scheduler applied an escalation rung to a tripped slot.
        ``tick`` lets the scheduler pace escalation: it skips a slot
        whose last action is newer than the observation lag, so a rung
        gets one validated read before the next rung fires."""
        inc = self._pending.get(slot)
        if inc is None:
            # An action outside an incident (e.g. a step retry that never
            # tripped a slot rule) still counts process-wide.
            self.recoveries[action] += 1
            _COUNTERS[f"recoveries_{action}"] += 1
            return
        inc["actions"].append(action)
        inc["last_action_tick"] = int(tick)

    def slot_failed(self, slot: int, tick: int, action: str) -> None:
        """Terminal rung: the slot was retired with an error — the
        incident closes as recovered-by-containment."""
        inc = self._pending.get(slot)
        if inc is None:
            self._pending[slot] = inc = {
                "tick": tick, "kind": "unknown", "actions": [],
            }
        inc["actions"].append(action)
        self._close(slot, tick, inc)

    def slot_reset(self, slot: int) -> None:
        """Admission/retire: the slot's history belongs to a dead request."""
        self._collapse[slot] = 0
        self._diverge[slot] = 0
        self._pending.pop(slot, None)

    def slot_moved(self, src: int, dst: int) -> None:
        """A migration moved the request (and any open incident) with it."""
        self._collapse[dst] = self._collapse[src]
        self._diverge[dst] = self._diverge[src]
        if src in self._pending:
            self._pending[dst] = self._pending.pop(src)
        self._collapse[src] = 0
        self._diverge[src] = 0

    # -- wall-clock watchdog --------------------------------------------

    def step_watchdog(self, elapsed_ms: float) -> bool:
        """True when a step's wall latency exceeds the timeout (counted)."""
        cfg = self.config
        if cfg.step_timeout_ms is None or elapsed_ms <= cfg.step_timeout_ms:
            return False
        self.watchdog_trips += 1
        _COUNTERS["watchdog_trips"] += 1
        return True

    def step_retried(self) -> None:
        self.step_retries += 1
        _COUNTERS["step_retries"] += 1

    # -- reporting ------------------------------------------------------

    @property
    def stats(self) -> dict:
        return {
            "trips": dict(self.trips),
            "recoveries": dict(self.recoveries),
            "events": list(self.events),
            "recovered": list(self.recovered),
            "open_incidents": {
                s: dict(inc) for s, inc in self._pending.items()
            },
            "watchdog_trips": self.watchdog_trips,
            "step_retries": self.step_retries,
        }
