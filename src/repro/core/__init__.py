"""Core: the paper's half-precision particle filter as a composable library.

Layers:
- ``precision``   — multi-precision policies (fp64/fp32/bf16/fp16 ± stability)
- ``stability``   — scaled-square, log-sum-exp, online/streaming LSE combine
- ``likelihood``  — Rodinia intensity observation model (naive + stable)
- ``resampling``  — systematic / stratified / multinomial
- ``filter``      — generic SMC step/scan (propagate → weight → resample)
- ``tracking``    — the paper's object-tracking application
- ``distributed`` — shard_map multi-device filter with hierarchical resampling
"""

from repro.core.filter import (  # noqa: F401
    FilterOutput,
    FilterState,
    SMCSpec,
    pf_init,
    pf_scan,
    pf_step,
)
from repro.core.precision import (  # noqa: F401
    POLICIES,
    PrecisionPolicy,
    get_policy,
)
from repro.core.tracking import TrackerConfig, track  # noqa: F401
