"""Core: the paper's half-precision particle filter as a composable library.

Layers:
- ``precision``   — multi-precision policies (fp64/fp32/bf16/fp16 ± stability)
- ``stability``   — scaled-square, log-sum-exp, online/streaming LSE combine
- ``likelihood``  — Rodinia intensity observation model (naive + stable)
- ``resampling``  — systematic / stratified / multinomial (registry)
- ``filter``      — SMC model/state types (SMCSpec, FilterState, FilterOutput)
- ``engine``      — the ParticleFilter engine: FilterConfig-dispatched
  backends (jnp / pallas), resamplers, and mesh distribution behind one
  ``init`` / ``step`` / ``run`` / ``stream`` API
- ``elastic``     — ESS-driven particle-budget autoscaling for FilterBanks
  (BudgetController + the engine's ``resize_slot`` budget switch)
- ``health``      — per-slot health sentinels (non-finite state, weight
  collapse, evidence divergence, stuck steps) + the step watchdog
- ``faults``      — deterministic run-key-derived fault injection (the
  chaos harness behind the serve escalation ladder)
- ``tracking``    — the paper's object-tracking application
- ``distributed`` — shard_map multi-device step (exact / local-RNA schemes),
  reached via ``FilterConfig(mesh=...)``
"""

from repro.core.elastic import (  # noqa: F401
    BudgetController,
    BudgetDecision,
    ElasticConfig,
)
from repro.core.engine import (  # noqa: F401
    BACKENDS,
    Backend,
    FilterBank,
    FilterConfig,
    ParticleFilter,
    get_backend,
    register_backend,
)
from repro.core.faults import (  # noqa: F401
    ChaosConfig,
    FaultInjector,
)
from repro.core.filter import (  # noqa: F401
    FilterOutput,
    FilterState,
    SMCSpec,
)
from repro.core.health import (  # noqa: F401
    HealthConfig,
    HealthMonitor,
)
from repro.core.precision import (  # noqa: F401
    POLICIES,
    PrecisionPolicy,
    get_policy,
    register_policy,
)
from repro.core.resampling import (  # noqa: F401
    get_resampler,
    register_resampler,
)
from repro.core.tracking import (  # noqa: F401
    TrackerConfig,
    make_multi_tracker_filter,
    make_tracker_filter,
)
