"""Elastic particle budgets: ESS-driven autoscaling inside a FilterBank.

Ragged banks (``FilterBank.init(..., n_active=...)``) made per-slot
particle counts a runtime value; this module *changes* a live slot's
budget.  The production econ story on top of the paper's
fixed-cost-per-particle win is spending particles where the posterior is
hard: resampling (and every other per-particle stage) costs linearly in
the count (Murray, arXiv:1202.6163), so at serving scale the right
allocation grows a slot whose ESS collapses — hard tracking — and shrinks
one whose ESS is comfortably above target — easy tracking.

The split of responsibilities:

- :class:`BudgetController` (here) is a small *host-side* control loop.
  Once per scheduler tick it reads each slot's effective sample size —
  already produced for free by the fused weight epilogue's
  ``sum_w``/``sum_w2`` stats (``FilterOutput.ess``) — and proposes
  power-of-two budget changes with hysteresis and a per-slot cooldown so
  noisy ESS estimates cannot make a slot oscillate.  When a fixed global
  particle budget is configured and total demand exceeds it, a bank-level
  arbiter grants grows in order of ESS *deficit* (the slots furthest
  below target first) and denies the rest.
- The budget switch itself is a device-side primitive:
  ``FilterBank.resize_slot(state, slot, key, n)`` draws a count-aware
  systematic sample of the new count from the slot's current posterior
  (resample-down = the in-VMEM CDF draw truncated to ``k`` lanes;
  resample-up = a re-draw at ``k`` with the stored ``log_uniform``
  reset), reusing the masked resample kernels unchanged.  Slot index and
  count are both traced, so budget transitions never recompile — the same
  contract ragged admission already relies on.

Thresholds are *absolute* ESS values, not fractions of the current count:
for the weight profiles a filter produces, ESS scales roughly linearly
with the particle count at fixed tracking difficulty (iid weights give
``ESS ~ n / E[w^2]/E[w]^2``), so "keep at least E effective particles"
is the controllable target — growing a slot raises its ESS toward the
band, shrinking lowers it — whereas the ESS *fraction* is roughly
count-invariant and would bang-bang every slot to the min or max budget.

Deadband: ``shrink_above >= 2 * grow_below`` is enforced.  A granted grow
doubles the count and therefore roughly doubles the ESS; a granted shrink
halves both.  With the factor-2 step inside a >= factor-2 band, one
granted change can never land the slot directly in the opposite trigger
region, and the cooldown absorbs the noise on top of that model.

Typical wiring (the continuous-batching scheduler in
``repro.launch.serve`` does exactly this under ``--elastic``)::

    ctrl = BudgetController(ElasticConfig(grow_below=64.0,
                                          min_particles=256,
                                          max_particles=4096), nb)
    ...
    state, out = bank.jit_step(state, obs, keys)        # one tick
    for d in ctrl.observe(np.asarray(out.ess), budgets, busy):
        if d.granted:
            state = bank.jit_resize_slot(
                state, jnp.int32(d.slot), key, jnp.int32(d.new)
            )
            budgets[d.slot] = d.new
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "BudgetController",
    "BudgetDecision",
    "ElasticConfig",
]


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Knobs of the ESS-driven budget controller.

    grow_below:    absolute ESS floor — a busy slot whose ESS falls below
                   it doubles its budget (hard tracking).
    shrink_above:  absolute ESS ceiling — a busy slot whose ESS exceeds it
                   halves its budget (easy tracking).  Defaults to
                   ``4 * grow_below``; must be >= ``2 * grow_below`` (the
                   deadband that keeps one factor-2 step from landing in
                   the opposite trigger region).
    cooldown:      ticks a slot is ineligible for another change after a
                   granted one — the oscillation damper for noisy ESS
                   estimates.
    min_particles / max_particles: budget clamp; steps are powers of two
                   from the current count, so admission-class budgets stay
                   on their ladder and every transition is the traced
                   recompile-free ``resize_slot`` path.
    global_budget: optional cap on the *total* active particles across
                   busy slots.  When total demand exceeds it, the arbiter
                   grants grows by ESS deficit (furthest below
                   ``grow_below`` first) and denies the rest; shrinks are
                   always granted (they free budget).  None = uncapped.
    reseed_after:  failure-recovery escalation.  A busy slot already at
                   ``max_particles`` whose ESS stays below ``grow_below``
                   has nothing left to grow — once the collapse persists
                   for this many consecutive ticks the controller emits a
                   ``kind="reseed"`` decision (apply via
                   ``FilterBank.reseed_slot``: a fresh diffuse-prior cloud
                   at MAX, step counter kept — the request stays
                   mid-flight).  None (default) disables the escalation.
    """

    grow_below: float
    min_particles: int
    max_particles: int
    shrink_above: float | None = None
    cooldown: int = 2
    global_budget: int | None = None
    reseed_after: int | None = None

    def __post_init__(self) -> None:
        if not self.grow_below > 0:
            raise ValueError(
                f"grow_below must be > 0, got {self.grow_below}"
            )
        if self.shrink_above is None:
            object.__setattr__(
                self, "shrink_above", 4.0 * self.grow_below
            )
        if self.shrink_above < 2.0 * self.grow_below:
            raise ValueError(
                f"shrink_above={self.shrink_above} must be >= "
                f"2 * grow_below={2.0 * self.grow_below}: a factor-2 "
                "budget step roughly doubles/halves the ESS, so a "
                "narrower band lets one granted change land in the "
                "opposite trigger region (oscillation)"
            )
        if self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")
        if not 1 <= self.min_particles <= self.max_particles:
            raise ValueError(
                f"need 1 <= min_particles <= max_particles, got "
                f"{self.min_particles}:{self.max_particles}"
            )
        if (
            self.global_budget is not None
            and self.global_budget < self.min_particles
        ):
            raise ValueError(
                f"global_budget={self.global_budget} cannot admit even "
                f"one slot at min_particles={self.min_particles}"
            )
        if self.reseed_after is not None and self.reseed_after < 1:
            raise ValueError(
                f"reseed_after must be >= 1 ticks of persistent collapse "
                f"(or None to disable), got {self.reseed_after}"
            )


@dataclasses.dataclass(frozen=True)
class BudgetDecision:
    """One proposed budget change for one slot on one tick.

    ``granted=False`` marks a grow the global-budget arbiter denied (the
    slot stays at ``old``; it retries on later ticks while the trigger
    holds).  ``deficit`` is the ESS shortfall the arbiter ranked by.

    ``migrate=True`` marks a granted grow whose new budget exceeds the
    slot's current *lane width* (only possible when the caller passed
    ``observe(..., lane_width=...)`` — the multi-bank packer): the resize
    cannot happen in-bank; the caller must move the slot to a wider bank
    (``FilterBank.export_slot`` → ``import_slot``) or report the move
    blocked (``BudgetController.migration_blocked``).

    ``kind="reseed"`` is the failure-recovery escalation (``old == new``:
    no budget change — the slot's cloud is re-drawn from the prior at its
    current budget via ``FilterBank.reseed_slot``).

    ``reason`` distinguishes *why* a grow was denied: ``"budget"`` (the
    global particle cap) vs ``"latency"`` (the slot's bank is already
    over its per-tick deadline — more lanes would push it further over).
    Empty for granted decisions.
    """

    slot: int
    old: int
    new: int
    ess: float
    kind: str  # "grow" | "shrink" | "reseed"
    granted: bool = True
    deficit: float = 0.0
    migrate: bool = False
    reason: str = ""


class BudgetController:
    """Per-tick ESS watcher proposing budget switches for a FilterBank.

    Host-side and stateless about the budgets themselves (the scheduler
    owns those); the controller keeps only per-slot cooldown counters and
    event counters.  Call :meth:`observe` once per bank step with that
    tick's per-slot ESS (``FilterOutput.ess``), the current per-slot
    budgets, and the busy mask; apply the granted decisions via
    ``FilterBank.resize_slot``.  Call :meth:`slot_admitted` when a request
    enters a slot so a fresh request gets ``cooldown`` ticks of grace
    before its first (still noisy) ESS reading can resize it.
    """

    def __init__(self, config: ElasticConfig, num_slots: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.config = config
        self.num_slots = num_slots
        self._cooldown = np.zeros(num_slots, np.int64)
        # Consecutive ticks each slot has sat collapsed (ESS < grow_below)
        # at max_particles — the reseed_after escalation trigger.
        self._collapse = np.zeros(num_slots, np.int64)
        self.grows = 0
        self.shrinks = 0
        self.denied = 0
        self.denied_latency = 0
        self.reseeds = 0

    def slot_admitted(self, slot: int) -> None:
        """A request just entered ``slot``: start it on a full cooldown."""
        self._cooldown[slot] = self.config.cooldown
        self._collapse[slot] = 0

    def slot_moved(self, src: int, dst: int) -> None:
        """The scheduler migrated the request in ``src`` to ``dst`` (a
        cross-bank move in the packed scheduler): its cooldown and
        collapse history travel with it; the vacated slot resets."""
        self._cooldown[dst] = self._cooldown[src]
        self._collapse[dst] = self._collapse[src]
        self._cooldown[src] = 0
        self._collapse[src] = 0

    def migration_blocked(self, slot: int) -> None:
        """A ``migrate=True`` grow could not be placed (no free slot in
        any wide-enough bank): reclassify it as denied.  The cooldown
        stays charged — backoff before retrying a placement that just
        failed."""
        self.grows -= 1
        self.denied += 1

    def observe(
        self,
        ess: np.ndarray,
        n_active: np.ndarray,
        busy: np.ndarray,
        lane_width: np.ndarray | None = None,
        lane_p95_ms: np.ndarray | None = None,
        deadline_ms: float | None = None,
    ) -> list[BudgetDecision]:
        """One tick: propose and arbitrate budget changes.

        ess:      (B,) per-slot effective sample sizes.  Any non-finite
                  or negative reading — NaN, ±Inf, garbage from a
                  corrupted weight row — counts as 0, i.e. full
                  collapse: it triggers a grow (and accrues toward
                  ``reseed_after``), and can never satisfy
                  ``shrink_above`` (a +Inf "ESS" is a poisoned
                  accumulator, not an easy slot to shrink).
        n_active: (B,) current per-slot budgets.
        busy:     (B,) bool — slots holding a live request; idle slots are
                  never resized (their lanes are junk anyway).
        lane_width: optional (B,) static lane width of each slot's bank —
                  the multi-bank packer, where slot budgets are capped by
                  the bank they currently sit in.  A granted grow whose
                  new budget exceeds its slot's width is flagged
                  ``migrate=True``: the caller must move the slot to a
                  wider bank (or call :meth:`migration_blocked`).  None
                  (single-bank scheduler): all resizes are in-bank.
        lane_p95_ms / deadline_ms: the latency-aware arbiter.  When both
                  are given, a grow on a slot whose bank's p95 step
                  wall-time already exceeds the deadline is denied
                  (``reason="latency"``) *before* the budget arbiter
                  runs — more lanes on an already-late bank trades the
                  whole bank's SLO for one slot's ESS.  Latency denials
                  charge no cooldown (the slot retries when the bank
                  catches up) and are counted separately
                  (``denied_grows_latency``) from budget denials.

        Returns every decision made this tick, granted or denied, in
        application order.  Only entries with ``granted=True`` change a
        budget; the caller applies them via ``resize_slot`` (or the
        export/import migration pair) and updates its own budget array.
        """
        cfg = self.config
        ess = np.asarray(ess, np.float64).copy()
        # Harden against poisoned stats: ±Inf and NaN are collapse (a
        # non-finite accumulator means the weight row is corrupt), and a
        # negative reading is garbage that must never look "healthy
        # enough to shrink".  All map to 0 = the strongest grow trigger.
        ess[~np.isfinite(ess) | (ess < 0)] = 0.0
        n = np.asarray(n_active, np.int64)
        busy = np.asarray(busy, bool)
        if ess.shape != (self.num_slots,) or n.shape != (self.num_slots,):
            raise ValueError(
                f"ess/n_active must be shaped ({self.num_slots},), got "
                f"{ess.shape} / {n.shape}"
            )
        if lane_width is not None:
            lane_width = np.asarray(lane_width, np.int64)
            if lane_width.shape != (self.num_slots,):
                raise ValueError(
                    f"lane_width must be shaped ({self.num_slots},), got "
                    f"{lane_width.shape}"
                )
        late = np.zeros(self.num_slots, bool)
        if lane_p95_ms is not None and deadline_ms is not None:
            lane_p95_ms = np.asarray(lane_p95_ms, np.float64)
            if lane_p95_ms.shape != (self.num_slots,):
                raise ValueError(
                    f"lane_p95_ms must be shaped ({self.num_slots},), got "
                    f"{lane_p95_ms.shape}"
                )
            late = lane_p95_ms > float(deadline_ms)

        # Cooldowns tick down first; slots at zero are eligible.
        np.maximum(self._cooldown - 1, 0, out=self._cooldown)
        eligible = busy & (self._cooldown == 0)

        shrink = eligible & (ess > cfg.shrink_above) & (n > cfg.min_particles)
        grow = eligible & (ess < cfg.grow_below) & (n < cfg.max_particles)

        # Failure-recovery escalation: collapse persistence is tracked on
        # the raw trigger (independent of cooldown) so a reseed cannot be
        # starved by its own cooldown charges.
        collapsed = busy & (ess < cfg.grow_below) & (n >= cfg.max_particles)
        self._collapse[collapsed] += 1
        self._collapse[~collapsed] = 0

        decisions: list[BudgetDecision] = []
        # Shrinks first — always granted, and under a global budget they
        # free lanes the grow pass below can hand out.
        total = int(n[busy].sum())
        for slot in np.flatnonzero(shrink):
            new = max(int(n[slot]) // 2, cfg.min_particles)
            total += new - int(n[slot])
            decisions.append(
                BudgetDecision(
                    slot=int(slot),
                    old=int(n[slot]),
                    new=new,
                    ess=float(ess[slot]),
                    kind="shrink",
                )
            )
            self._cooldown[slot] = cfg.cooldown
            self.shrinks += 1

        # Grows by ESS deficit: the slots furthest below target first, so
        # a constrained global budget goes where the posterior is hardest.
        order = sorted(
            np.flatnonzero(grow),
            key=lambda s: (-(cfg.grow_below - ess[s]), s),
        )
        for slot in order:
            new = min(int(n[slot]) * 2, cfg.max_particles)
            extra = new - int(n[slot])
            deficit = float(cfg.grow_below - ess[slot])
            if late[slot]:
                # Latency-aware denial: the slot's bank is already over
                # its per-tick deadline — a grow would add step work to
                # every tick of an already-late bank.  No cooldown
                # charge: the slot retries once the bank's p95 recovers.
                decisions.append(
                    BudgetDecision(
                        slot=int(slot),
                        old=int(n[slot]),
                        new=int(n[slot]),
                        ess=float(ess[slot]),
                        kind="grow",
                        granted=False,
                        deficit=deficit,
                        reason="latency",
                    )
                )
                self.denied_latency += 1
                continue
            if (
                cfg.global_budget is not None
                and total + extra > cfg.global_budget
            ):
                # Denied: no cooldown charge — the slot retries as soon
                # as a shrink or a retire frees lanes.
                decisions.append(
                    BudgetDecision(
                        slot=int(slot),
                        old=int(n[slot]),
                        new=int(n[slot]),
                        ess=float(ess[slot]),
                        kind="grow",
                        granted=False,
                        deficit=deficit,
                        reason="budget",
                    )
                )
                self.denied += 1
                continue
            total += extra
            decisions.append(
                BudgetDecision(
                    slot=int(slot),
                    old=int(n[slot]),
                    new=new,
                    ess=float(ess[slot]),
                    kind="grow",
                    deficit=deficit,
                    migrate=(
                        lane_width is not None
                        and new > int(lane_width[slot])
                    ),
                )
            )
            self._cooldown[slot] = cfg.cooldown
            self.grows += 1

        # Reseed escalation: a slot with nothing left to grow whose
        # collapse has persisted long enough.  No particle-count change,
        # so the global-budget arbiter is not involved.
        if cfg.reseed_after is not None:
            for slot in np.flatnonzero(
                eligible
                & collapsed
                & (self._collapse >= cfg.reseed_after)
            ):
                decisions.append(
                    BudgetDecision(
                        slot=int(slot),
                        old=int(n[slot]),
                        new=int(n[slot]),
                        ess=float(ess[slot]),
                        kind="reseed",
                    )
                )
                self._cooldown[slot] = cfg.cooldown
                self._collapse[slot] = 0
                self.reseeds += 1
        return decisions

    @property
    def stats(self) -> dict:
        return {
            "grows": self.grows,
            "shrinks": self.shrinks,
            "denied_grows": self.denied,
            "denied_grows_latency": self.denied_latency,
            "reseeds": self.reseeds,
        }
