"""The paper's object-tracking application, assembled from the core pieces.

State per particle: (row, col) position.  Transition (paper Eqs. 1-2):

    row' ~ N(row + 1, 5^2),   col' ~ N(col + 2, 2^2)

Noise is drawn in wide precision and *then* cast to the target dtype,
matching the paper's cuRAND-double → half conversion path.  Likelihood is
the Rodinia intensity-disk model (``repro.core.likelihood``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import likelihood as lik
from repro.core.engine import (
    FilterBank,
    FilterConfig,
    ParticleFilter,
    get_backend,
)
from repro.core.filter import SMCSpec, StepFusion
from repro.core.precision import PrecisionPolicy

__all__ = [
    "TrackerConfig",
    "make_tracker_spec",
    "make_tracker_filter",
    "make_multi_tracker_filter",
]


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    num_particles: int = 1024
    radius: int = 4
    height: int = 512
    width: int = 512
    # Transition model (paper Eq. 1-2): mean drift and std per coordinate.
    drift: tuple[float, float] = (1.0, 2.0)
    std: tuple[float, float] = (5.0, 2.0)
    resampler: str = "systematic"
    ess_threshold: float = 1.0  # resample every frame, like Rodinia
    backend: str = "jnp"  # or "pallas"


def make_tracker_spec(
    cfg: TrackerConfig,
    policy: PrecisionPolicy,
    start: jax.Array | None = None,
    *,
    starts: jax.Array | None = None,
) -> SMCSpec:
    """The tracker as an SMC model.

    ``start`` seeds the single-filter init cloud ((2,), default frame
    center).  ``starts`` ((B, 2)) instead populates the spec's banked
    ``slot_init`` hook: under a :class:`~repro.core.engine.FilterBank` each
    slot draws its cloud around its own row — one tracked target per slot.
    """
    model = lik.IntensityModel(radius=cfg.radius)
    offsets = model.offsets
    # Paper: noise is drawn in double precision and *converted* to the
    # target dtype (cuRAND has no half).  With x64 enabled every policy
    # shares the same fp64 draw stream (the paper's same-seed methodology);
    # without it, fp32 draws cast down.
    from repro.core.precision import has_x64

    draw_dtype = (
        jnp.float64
        if (policy.compute_dtype == jnp.float64 or has_x64())
        else jnp.float32
    )
    drift = jnp.asarray(cfg.drift, draw_dtype)
    std = jnp.asarray(cfg.std, draw_dtype)

    def init_at(key, num_particles, center):
        jitter = jax.random.normal(key, (num_particles, 2), draw_dtype)
        return {"pos": center.astype(draw_dtype) + std * jitter}

    def init(key, num_particles):
        center = (
            jnp.asarray(
                [cfg.height / 2.0, cfg.width / 2.0], draw_dtype
            )
            if start is None
            else start
        )
        return init_at(key, num_particles, center)

    slot_init = None
    if starts is not None:
        starts_arr = jnp.asarray(starts)

        def slot_init(key, num_particles, slot):
            return init_at(key, num_particles, starts_arr[slot])

    def transition(key, particles, step):
        del step
        pos = particles["pos"]
        # Draw wide, then cast down — the paper's cuRAND-double path.
        noise = jax.random.normal(key, pos.shape, draw_dtype)
        new = pos.astype(draw_dtype) + drift + std * noise
        new = jnp.clip(
            new,
            0.0,
            jnp.asarray(
                [cfg.height - 1.0, cfg.width - 1.0], draw_dtype
            ),
        )
        return {"pos": new.astype(policy.compute_dtype)}

    # Likelihood dispatch goes through the backend registry — any backend
    # exposing an ``intensity_loglik`` hook (the fused Pallas kernel does)
    # gets it; the rest fall back to the pure-jnp reference.  Resolved at
    # spec-build time so unknown backend names fail fast.
    backend_loglik = get_backend(cfg.backend).intensity_loglik

    def loglik(particles, frame, step):
        del step
        patches = lik.gather_patches(frame, particles["pos"], offsets)
        if backend_loglik is not None:
            return backend_loglik(patches, model, policy)
        return lik.intensity_loglik(patches, model, policy)

    # The fusable structure of ``loglik`` (gather + intensity model),
    # letting the engine stream likelihood → weights → resample in one
    # pass (``FilterConfig.fused_step``).  Gated to this config's backend:
    # the fused path must score patches with the same kernel the composed
    # ``loglik`` dispatches, or the bitwise contract breaks.
    def gather_obs(particles, frame, step):
        del step
        return lik.gather_patches(frame, particles["pos"], offsets)

    return SMCSpec(
        init=init,
        transition=transition,
        loglik=loglik,
        slot_init=slot_init,
        step_fusion=StepFusion(
            gather=gather_obs, model=model, backend=cfg.backend
        ),
    )


def make_tracker_filter(
    cfg: TrackerConfig,
    policy: PrecisionPolicy,
    start: jax.Array | None = None,
    filter_config: FilterConfig | None = None,
) -> ParticleFilter:
    """The tracker as a configured engine.

    ``filter_config`` overrides the execution axes wholesale (e.g. to hand
    the tracker a mesh); otherwise the TrackerConfig's resampler /
    ess_threshold / backend fields are used.

        flt = make_tracker_filter(cfg, policy)
        final, outs = flt.run(key, video, cfg.num_particles)
        trajectory = outs.estimate["pos"]
    """
    spec = make_tracker_spec(cfg, policy, start)
    if filter_config is None:
        filter_config = FilterConfig(
            policy=policy,
            backend=cfg.backend,
            resampler=cfg.resampler,
            ess_threshold=cfg.ess_threshold,
        )
    else:
        filter_config = filter_config.with_(policy=policy)
    return ParticleFilter(spec, filter_config)


def make_multi_tracker_filter(
    cfg: TrackerConfig,
    policy: PrecisionPolicy,
    starts: jax.Array,
    filter_config: FilterConfig | None = None,
    budgets: jax.Array | None = None,
) -> FilterBank:
    """N-target tracker: one FilterBank slot per row of ``starts`` ((B, 2)).

    All targets share the transition/likelihood model and one frame stream;
    each slot draws its initial cloud around its own start position and
    filters independently (per-slot weights, ESS, resampling).

        bank = make_multi_tracker_filter(cfg, policy, starts)
        final, outs = bank.run(key, video, cfg.num_particles)
        trajectories = outs.estimate["pos"]        # (T, B, 2)

    Lost targets can be re-acquired mid-stream without recompiling:
    ``state = bank.reset_slot(state, slot, key)`` redraws that slot's cloud
    at its start position.

    ``budgets`` ((B,) ints) gives each target its own particle budget — the
    ragged bank: ``cfg.num_particles`` stays the lane width, but target
    ``b`` filters with ``budgets[b]`` active particles (easy targets track
    at a fraction of the width a hard target needs).  The counts become
    the bank's ``default_n_active``, picked up by ``init``/``run``; a
    reset can re-admit a target at any traced count
    (``bank.reset_slot(state, slot, key, n_active=n)``).

    Meshed multi-object mode: hand the bank a mesh through
    ``filter_config`` and targets shard over "data" while each target's
    particles shard over "model" — the same trajectories API at
    multi-device scale (the distributed schemes resample every frame)::

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bank = make_multi_tracker_filter(
            cfg, policy, starts,                   # len(starts) % 2 == 0
            FilterConfig(mesh=mesh, scheme="local"))
    """
    starts = jnp.asarray(starts)
    if starts.ndim != 2 or starts.shape[-1] != 2:
        raise ValueError(f"starts must be (num_targets, 2), got {starts.shape}")
    spec = make_tracker_spec(cfg, policy, starts=starts)
    if filter_config is None:
        filter_config = FilterConfig(
            policy=policy,
            backend=cfg.backend,
            resampler=cfg.resampler,
            ess_threshold=cfg.ess_threshold,
        )
    else:
        filter_config = filter_config.with_(policy=policy)
    bank = FilterBank(spec, filter_config, num_slots=starts.shape[0])
    if budgets is not None:
        budgets = jnp.asarray(budgets, jnp.int32)
        if budgets.shape != (starts.shape[0],):
            raise ValueError(
                f"budgets must be shaped ({starts.shape[0]},) — one count "
                f"per target — got {budgets.shape}"
            )
        bank.default_n_active = budgets
    return bank
