"""Numerical-stability primitives from the paper, in reusable form.

Three tricks, each mapped from the paper's CUDA context to framework-wide
JAX utilities:

1. **Scaled square** (paper Eq. 3 → Eq. 4): ``sum((a-c)^2)/s`` overflows in
   fp16 when the un-divided squares or their running sum exceed 65504; moving
   the scale *inside* the square bounds every intermediate.
2. **Log-sum-exp weighting** (paper Eq. 5): ``w = exp(L)`` overflows /
   vanishes; subtracting the max keeps every exponent ≤ 0.  ``logsumexp``
   here is the two-pass reference; the fused one-pass online version lives in
   ``repro.kernels.logsumexp`` (our beyond-paper fusion of the paper's
   max-finding + weighting + normalizing kernel chain).
3. **Online (streaming) LSE combine**: the flash-attention-style running
   ``(max, rescaled sum)`` pair.  Used by the Pallas kernel, by the
   distributed particle filter (cross-device combine with ``pmax``/``psum``)
   and by seq-sharded decode attention in the LM stack.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "scaled_square_diff",
    "logsumexp",
    "normalize_log_weights",
    "stable_softmax",
    "LseState",
    "lse_init",
    "lse_update",
    "lse_combine",
    "lse_finalize",
    "effective_sample_size",
]


def scaled_square_diff(x: jax.Array, center, inv_sqrt_scale) -> jax.Array:
    """((x - center) * inv_sqrt_scale)**2 — every intermediate is O(1).

    Equivalent to (x - center)**2 / scale with inv_sqrt_scale = scale**-0.5,
    but safe in fp16 (paper Eq. 4).  ``inv_sqrt_scale`` is expected to be a
    precomputed constant — the TPU analogue of the paper's hoisting of
    reciprocals out of the XU pipeline.
    """
    d = (x - center) * inv_sqrt_scale
    return d * d


def logsumexp(x: jax.Array, axis=-1, keepdims: bool = False):
    """Two-pass max-subtracted logsumexp in the input dtype.

    Matches the paper's scheme: one max reduction, then sum of exp(x - max).
    NaN-safe for all -inf rows (returns -inf rather than NaN).
    """
    m = jnp.max(x, axis=axis, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = jnp.sum(jnp.exp(x - m_safe), axis=axis, keepdims=True)
    out = m_safe + jnp.log(s)
    out = jnp.where(jnp.isfinite(m), out, m)  # all -inf -> -inf
    if not keepdims:
        out = jnp.squeeze(out, axis=axis)
    return out


def normalize_log_weights(log_w: jax.Array, *, stable: bool = True):
    """log weights -> (normalized weights, log normalizer).

    ``stable=False`` reproduces the paper's naive path (direct ``exp``),
    which overflows for fp16 at realistic likelihood magnitudes — kept so the
    failure mode is testable.
    """
    if stable:
        lse = logsumexp(log_w, axis=-1, keepdims=True)
        lse_safe = jnp.where(jnp.isfinite(lse), lse, jnp.zeros_like(lse))
        w = jnp.exp(log_w - lse_safe)  # all -inf -> all-zero weights, no NaN
        return w, jnp.squeeze(lse, axis=-1)
    raw = jnp.exp(log_w)
    total = jnp.sum(raw, axis=-1, keepdims=True)
    return raw / total, jnp.squeeze(jnp.log(total), axis=-1)


def stable_softmax(x: jax.Array, axis=-1, *, accum_dtype=None) -> jax.Array:
    """Max-subtracted softmax with optional wider accumulation dtype.

    This is the LM-side landing of the paper's Eq.-5 trick: attention and
    router softmaxes run their reductions in ``accum_dtype`` (fp32 by
    default) while inputs/outputs stay in the compute dtype.
    """
    dt = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    m = jnp.max(x, axis=axis, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    e = jnp.exp(x - m)
    out = e / jnp.sum(e, axis=axis, keepdims=True)
    return out.astype(dt)


class LseState(NamedTuple):
    """Running (max, rescaled sum of exp) pair."""

    m: jax.Array
    s: jax.Array


def lse_init(shape=(), dtype=jnp.float32) -> LseState:
    return LseState(
        m=jnp.full(shape, -jnp.inf, dtype=dtype),
        s=jnp.zeros(shape, dtype=dtype),
    )


def lse_update(state: LseState, x: jax.Array, axis=-1) -> LseState:
    """Fold a new block of log-values into the running state (one pass)."""
    bm = jnp.max(x, axis=axis)
    m_new = jnp.maximum(state.m, bm)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, jnp.zeros_like(m_new))
    s_new = state.s * jnp.exp(state.m - m_safe) + jnp.sum(
        jnp.exp(x - jnp.expand_dims(m_safe, axis)), axis=axis
    )
    # exp(-inf - 0) = 0 handles the empty initial state.
    return LseState(m=m_new, s=s_new)


def lse_combine(a: LseState, b: LseState) -> LseState:
    """Merge two partial LSE states (associative & commutative).

    This is the cross-device combine used by the distributed filter and by
    sequence-sharded decode attention: each shard reduces locally, then
    states merge with one pmax + one psum worth of traffic.
    """
    m = jnp.maximum(a.m, b.m)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = a.s * jnp.exp(a.m - m_safe) + b.s * jnp.exp(b.m - m_safe)
    return LseState(m=m, s=s)


def lse_finalize(state: LseState) -> jax.Array:
    """Running state -> logsumexp value."""
    out = state.m + jnp.log(state.s)
    return jnp.where(jnp.isfinite(state.m), out, state.m)


def effective_sample_size(weights: jax.Array, axis=-1) -> jax.Array:
    """ESS = (sum w)^2 / sum(w^2) — scale-invariant Kish form.

    The scale-invariant form matters in 16-bit: weights produced by
    ``exp(log_w - lse)`` carry an O(e^ulp(log_w)) common scale error.
    """
    return jnp.square(jnp.sum(weights, axis=axis)) / jnp.sum(
        jnp.square(weights), axis=axis
    )
