"""Precision policies — the paper's multi-precision axis as a first-class object.

The paper builds double-, single-, and half-precision specializations of the
same particle filter (``particleFilter<double>`` / ``<float>`` / ``<half>``)
and shows that the half-precision one is only correct *and* fast after
algorithmic changes (scaled-square likelihood, log-sum-exp weighting,
conversion-free kernels).  We encode that as a :class:`PrecisionPolicy` that
every layer of the framework (particle filter, LM stack, optimizer) consumes:

- ``param_dtype``   — storage dtype of persistent state (particles, weights,
  model parameters).
- ``compute_dtype`` — dtype arithmetic is performed in (the paper's FP16 on
  CUDA cores; bf16 on the TPU VPU/MXU).
- ``accum_dtype``   — dtype reductions/carries accumulate in.  The paper's
  pure-FP16 version accumulates in FP16; our TPU-native default keeps fp32
  accumulation (MXU behaviour) but ``*_pure`` policies reproduce the paper's
  all-half arithmetic exactly.
- ``stable_likelihood`` / ``stable_weighting`` — the paper's two algorithmic
  stability fixes (Eq. 4 scaled-square, Eq. 5 max-subtracted exponent).
  Naive policies switch them off to reproduce the paper's failure modes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "PrecisionPolicy",
    "get_policy",
    "register_policy",
    "POLICIES",
    "cast_tree",
    "has_x64",
]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype + numerical-stability configuration for one run."""

    name: str
    param_dtype: Any
    compute_dtype: Any
    accum_dtype: Any
    # The paper's algorithmic stability fixes (section 4).
    stable_likelihood: bool = True  # Eq. 4: scale inside the square
    stable_weighting: bool = True   # Eq. 5: exp(L - max L) via log-sum-exp
    # Loss scaling for fp16 *training* (LM side). Unused by the filter.
    loss_scale: float = 1.0

    def cast_compute(self, x: jax.Array) -> jax.Array:
        return x.astype(self.compute_dtype)

    def cast_accum(self, x: jax.Array) -> jax.Array:
        return x.astype(self.accum_dtype)

    def cast_param(self, x: jax.Array) -> jax.Array:
        return x.astype(self.param_dtype)

    @property
    def bits(self) -> int:
        return jnp.dtype(self.compute_dtype).itemsize * 8

    @property
    def is_half(self) -> bool:
        return self.bits == 16

    def with_(self, **kw: Any) -> "PrecisionPolicy":
        return dataclasses.replace(self, **kw)


def has_x64() -> bool:
    return bool(jax.config.read("jax_enable_x64"))


def _fp64() -> PrecisionPolicy:
    return PrecisionPolicy(
        name="fp64",
        param_dtype=jnp.float64,
        compute_dtype=jnp.float64,
        accum_dtype=jnp.float64,
    )


POLICIES: dict[str, PrecisionPolicy] = {}


def register_policy(policy: PrecisionPolicy) -> PrecisionPolicy:
    POLICIES[policy.name] = policy
    return policy


# The paper's three precisions.  fp64 is the baseline; fp32 matches it
# bit-for-bit on predictions in the paper; fp16 needs the stable forms.
register_policy(_fp64())
register_policy(
    PrecisionPolicy("fp32", jnp.float32, jnp.float32, jnp.float32)
)
# Paper-faithful pure halves: arithmetic *and* accumulation in 16 bit.
register_policy(
    PrecisionPolicy("fp16", jnp.float16, jnp.float16, jnp.float16)
)
register_policy(
    PrecisionPolicy("bf16", jnp.bfloat16, jnp.bfloat16, jnp.bfloat16)
)
# Naive halves: the paper's *unfixed* port — used to demonstrate overflow.
register_policy(
    PrecisionPolicy(
        "fp16_naive", jnp.float16, jnp.float16, jnp.float16,
        stable_likelihood=False, stable_weighting=False,
    )
)
register_policy(
    PrecisionPolicy(
        "bf16_naive", jnp.bfloat16, jnp.bfloat16, jnp.bfloat16,
        stable_likelihood=False, stable_weighting=False,
    )
)
# TPU-native deployment policies: 16-bit storage/compute, fp32 accumulation
# (what the MXU does for free; beyond-paper but the production default).
register_policy(
    PrecisionPolicy("bf16_mixed", jnp.bfloat16, jnp.bfloat16, jnp.float32)
)
register_policy(
    PrecisionPolicy(
        "fp16_mixed", jnp.float16, jnp.float16, jnp.float32,
        loss_scale=2.0 ** 12,
    )
)
# Weight-only 8-bit serving: parameters stored fp8-e4m3 (halves the
# HBM-read term that dominates decode), activations/arithmetic bf16 with
# fp32 reductions — the paper's "lower the storage precision, keep the
# math stable" discipline pushed one notch further (§Perf).
register_policy(
    PrecisionPolicy("bf16_w8", jnp.float8_e4m3fn, jnp.bfloat16, jnp.float32)
)


def get_policy(name: str) -> PrecisionPolicy:
    """Look up a policy; 'fp64' requires x64 to be enabled in this process."""
    try:
        policy = POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown precision policy {name!r}; have {sorted(POLICIES)}"
        ) from None
    if policy.name == "fp64" and not has_x64():
        raise RuntimeError(
            "policy 'fp64' needs jax_enable_x64; wrap the call in "
            "`with jax.enable_x64(True):` or set JAX_ENABLE_X64=1"
        )
    return policy


def cast_tree(tree: Any, dtype: Any) -> Any:
    """Cast every inexact leaf of a pytree to ``dtype``."""

    def _cast(x: jax.Array) -> jax.Array:
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)
