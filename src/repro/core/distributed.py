"""Multi-device particle filter: shard_map + hierarchical resampling.

The paper caps its filter at 64k particles on one GPU and identifies
resampling — the only stage with global dependence — as the dominant stage
at scale.  This module removes the cap: particles shard over an arbitrary
mesh axis set, and the two global stages become collectives:

- **Weight normalization** — each shard folds its log-weights into an online
  LSE state; states merge with one ``pmax`` + one ``psum`` (2 scalars of
  traffic per device, regardless of particle count).  This is the
  distributed form of the paper's Eq.-5 log-sum-exp.

- **Resampling** — two schemes:

  * ``exact``: global systematic resampling.  Per-shard CDF slices and
    particle states are all-gathered and every device selects the ancestors
    for its own output slice.  Bit-comparable to the single-device filter
    given the same u0; O(P·state_bytes) collective traffic (the baseline
    measured in §Perf).

  * ``local`` (RNA-style — resampling with nonproportional allocation):
    every device systematically resamples its own slice — zero particle
    exchange — and its offspring inherit the shard's global mass share as
    per-particle weights ``log(local_sum) - log(p_loc)``, keeping the
    estimator unbiased.  A periodic ring exchange (``ppermute`` of a
    particle block *with its weights*) mixes shards so per-shard weight
    variance stays bounded.  Collective bytes per step: O(D) scalars on
    normal steps, O(exchange_frac·P/D·state) on exchange steps — the
    beyond-paper collective-term optimization measured in §Perf.

Determinism: u0 derives from a key every device computes identically
(fold_in of the step), so exact-mode ancestry is reproducible across mesh
shapes — the property the elastic-reshard test relies on.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.precision import PrecisionPolicy

__all__ = [
    "SCHEMES",
    "DistributedConfig",
    "dist_normalize",
    "dist_systematic_exact",
    "dist_systematic_local",
    "make_dist_pf_step",
]

SCHEMES = ("exact", "local")


@dataclasses.dataclass(frozen=True)
class DistributedConfig:
    mesh: Any  # jax.sharding.Mesh
    axis: str | tuple[str, ...] = "data"  # particle-sharding mesh axes
    scheme: str = "exact"  # or "local"
    exchange_every: int = 4  # ring-exchange period for the local scheme
    exchange_frac: float = 0.25  # fraction of the local slice exchanged

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise KeyError(
                f"unknown resampling scheme {self.scheme!r}; "
                f"have {sorted(SCHEMES)}"
            )

    @property
    def axes(self) -> tuple[str, ...]:
        return (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)

    @property
    def num_shards(self) -> int:
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        n = 1
        for a in self.axes:
            n *= shape[a]
        return n


def _axis_index(axes: tuple[str, ...]) -> jax.Array:
    """Linearized device index along a tuple of mesh axes."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axis_size(axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def dist_normalize(log_w: jax.Array, axes: tuple[str, ...], accum_dtype):
    """Per-shard log-weights -> (normalized weights, global lse, global max).

    Runs inside shard_map.  Traffic: one pmax + one psum of a scalar.
    """
    x = log_w.astype(accum_dtype)
    m_loc = jnp.max(x)
    m = jax.lax.pmax(m_loc, axes)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = jax.lax.psum(jnp.sum(jnp.exp(x - m_safe)), axes)
    lse = jnp.where(jnp.isfinite(m), m_safe + jnp.log(s), m)
    w = jnp.exp(x - jnp.where(jnp.isfinite(lse), lse, 0.0))
    return w.astype(log_w.dtype), lse, m


def dist_systematic_exact(
    u0: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
) -> Any:
    """Global systematic resampling inside shard_map.

    weights: (P_loc,) globally normalized (psum over shards == 1).
    Returns resampled particles with the same local shapes.
    """
    p_loc = weights.shape[0]
    n_dev = _axis_size(axes)
    n_total = p_loc * n_dev
    d = _axis_index(axes)

    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32)
    sums = jax.lax.all_gather(local_sum, axes, tiled=False).reshape(-1)
    offset = jnp.sum(jnp.where(jnp.arange(n_dev) < d, sums, 0.0))
    cdf = offset + jnp.cumsum(w32)  # this shard's slice of the global CDF
    total = jnp.sum(sums)

    # Output positions owned by this device: g in [d*p_loc, (d+1)*p_loc).
    g = d * p_loc + jnp.arange(p_loc, dtype=jnp.float32)
    u = (g + u0.astype(jnp.float32)) * jnp.float32(1.0 / n_total) * total

    cdf_all = jax.lax.all_gather(cdf, axes, tiled=True)  # (P_total,)
    anc = jnp.clip(
        jnp.searchsorted(cdf_all, u, side="right"), 0, n_total - 1
    ).astype(jnp.int32)

    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axes, tiled=True), particles
    )
    return jax.tree.map(lambda x: jnp.take(x, anc, axis=0), gathered)


def dist_systematic_local(
    key: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
    *,
    step: jax.Array,
    exchange_every: int,
    exchange_frac: float,
    out_log_w_dtype,
) -> tuple[Any, jax.Array]:
    """RNA-style local resampling with periodic weighted ring exchange.

    weights: globally normalized local weights.  Offspring inherit the
    shard's mass share: log_w = log(local_sum) - log(p_loc).  Returns
    (particles, per-particle log-weights) — weights travel with exchanged
    particles so no separate shard-offset state is needed.
    """
    p_loc = weights.shape[0]
    d = _axis_index(axes)
    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32)

    u0 = jax.random.uniform(jax.random.fold_in(key, d), (), jnp.float32)
    cdf = jnp.cumsum(w32)
    cdf = cdf / cdf[-1]
    u = (jnp.arange(p_loc, dtype=jnp.float32) + u0) * jnp.float32(1.0 / p_loc)
    anc = jnp.clip(
        jnp.searchsorted(cdf, u, side="right"), 0, p_loc - 1
    ).astype(jnp.int32)
    res = jax.tree.map(lambda x: jnp.take(x, anc, axis=0), particles)
    log_w = jnp.full(
        (p_loc,), 0.0, jnp.float32
    ) + (jnp.log(local_sum) - jnp.log(jnp.float32(p_loc)))

    # Periodic ring exchange of the leading block, weights included.
    n_dev = _axis_size(axes)
    k = max(1, int(p_loc * exchange_frac))
    ring_axis = axes[-1]
    n_ring = compat.axis_size(ring_axis)
    perm = [(i, (i + 1) % n_ring) for i in range(n_ring)]

    def _exchange(args):
        ps, lw = args

        def swap(x):
            recv = jax.lax.ppermute(x[:k], ring_axis, perm)
            return jnp.concatenate([recv, x[k:]], axis=0)

        return jax.tree.map(swap, ps), swap(lw)

    do_x = jnp.logical_and(
        n_dev > 1, (step % exchange_every) == (exchange_every - 1)
    )
    res, log_w = jax.lax.cond(do_x, _exchange, lambda a: a, (res, log_w))
    return res, log_w.astype(out_log_w_dtype)


def make_dist_pf_step(
    spec,
    policy: PrecisionPolicy,
    cfg: DistributedConfig,
):
    """Build a shard_map'd PF step.

    Low-level: ``repro.core.engine.ParticleFilter`` wraps this behind the
    uniform ``step(state, obs, key)`` API when ``FilterConfig.mesh`` is set.

    Signature of the returned fn:
        (particles, log_w, step, obs, key) ->
        (particles, log_w, step+1, estimate, ess, lse, max_log_w)
    ``particles`` leaves and ``log_w`` are sharded on ``cfg.axes``; the
    observation and key are replicated.
    """
    axes = cfg.axes
    pspec = P(axes)

    def _step(particles, log_w, step, obs, key):
        k_prop, k_res = jax.random.split(jax.random.fold_in(key, 0))
        d = _axis_index(axes)
        particles = spec.transition(
            jax.random.fold_in(k_prop, d), particles, step
        )
        log_lik = spec.loglik(particles, obs, step).astype(
            policy.compute_dtype
        )
        log_w = log_w + log_lik
        w, lse, max_lw = dist_normalize(log_w, axes, policy.accum_dtype)

        wsum = jax.lax.psum(jnp.sum(w.astype(policy.accum_dtype)), axes)

        def _wmean(x):
            if not jnp.issubdtype(x.dtype, jnp.inexact):
                return x
            wx = w.astype(policy.accum_dtype).reshape(
                w.shape + (1,) * (x.ndim - 1)
            )
            return (
                jax.lax.psum(
                    jnp.sum(x.astype(policy.accum_dtype) * wx, axis=0), axes
                )
                / wsum
            )

        estimate = jax.tree.map(_wmean, particles)
        ess = jnp.square(wsum) / jax.lax.psum(
            jnp.sum(jnp.square(w.astype(policy.accum_dtype))), axes
        )

        p_loc = log_w.shape[0]
        if cfg.scheme == "exact":
            u0 = jax.random.uniform(k_res, (), jnp.float32)
            new_particles = dist_systematic_exact(u0, w, particles, axes)
            new_log_w = jnp.full(
                (p_loc,),
                -jnp.log(float(p_loc * cfg.num_shards)),
                policy.compute_dtype,
            )
        else:
            new_particles, new_log_w = dist_systematic_local(
                k_res,
                w,
                particles,
                axes,
                step=step,
                exchange_every=cfg.exchange_every,
                exchange_frac=cfg.exchange_frac,
                out_log_w_dtype=policy.compute_dtype,
            )
        return new_particles, new_log_w, step + 1, estimate, ess, lse, max_lw

    in_specs = (pspec, pspec, P(), P(), P())
    out_specs = (pspec, pspec, P(), P(), P(), P(), P())

    return compat.shard_map(
        _step,
        mesh=cfg.mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )
