"""Multi-device particle filter: shard_map + hierarchical resampling.

The paper caps its filter at 64k particles on one GPU and identifies
resampling — the only stage with global dependence — as the dominant stage
at scale.  This module removes the cap: particles shard over an arbitrary
mesh axis set, and the two global stages become collectives:

- **Weight normalization** — each shard folds its log-weights into an online
  LSE state; states merge with one ``pmax`` + one ``psum`` (2 scalars of
  traffic per device, regardless of particle count).  This is the
  distributed form of the paper's Eq.-5 log-sum-exp.

- **Resampling** — two schemes:

  * ``exact``: global systematic resampling.  Per-shard CDF slices and
    particle states are all-gathered and every device selects the ancestors
    for its own output slice.  Bit-comparable to the single-device filter
    given the same u0; O(P·state_bytes) collective traffic (the baseline
    measured in §Perf).

  * ``local`` (RNA-style — resampling with nonproportional allocation):
    every device systematically resamples its own slice — zero particle
    exchange — and its offspring inherit the shard's global mass share as
    per-particle weights ``log(local_sum) - log(p_loc)``, keeping the
    estimator unbiased.  A periodic ring exchange (``ppermute`` of a
    particle block *with its weights*) mixes shards so per-shard weight
    variance stays bounded.  Collective bytes per step: O(D) scalars on
    normal steps, O(exchange_frac·P/D·state) on exchange steps — the
    beyond-paper collective-term optimization measured in §Perf.

Determinism: u0 derives from a key every device computes identically
(fold_in of the step), so exact-mode ancestry is reproducible across mesh
shapes — the property the elastic-reshard test relies on.

The bank axis composes with the mesh (:func:`make_dist_bank_step`): a
:class:`~repro.core.engine.FilterBank`'s slots shard over
``DistributedConfig.bank_axis`` (``data`` by default) while each slot's
particles shard over ``axes`` (``model``).  Every weight collective spans
only the particle axes — slots are independent filters, so no traffic ever
crosses the bank axis — and the per-slot online-LSE states merge with one
``pmax`` + one ``psum`` of a ``(B_loc,)`` vector per device, i.e. one
collective launch per row-batch regardless of bank size.  The ``local``
scheme's ring exchange runs per slot over the particle ring; its period
gate is *per-slot* (slots admitted at different ticks carry different step
counters), so the ``ppermute`` always executes and each row selects
between the exchanged and kept block.

Ragged banks compose with both (``make_dist_bank_step(ragged=True)``):
per-slot active counts mask each shard's slice to the slot's global active
prefix — masked lanes enter the LSE merge at -inf and every collective
(pmax, psum, all-gather, ppermute) keeps its dense shape, so raggedness
costs no extra traffic; the exact scheme's systematic grid spans the
active count and the local scheme resamples each shard's active sub-slice
(ring-mixing only full-width slots — see
:func:`dist_systematic_local_banked`).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.precision import PrecisionPolicy

__all__ = [
    "SCHEMES",
    "DistributedConfig",
    "dist_lse_banked",
    "dist_lse_merge",
    "dist_normalize",
    "dist_normalize_banked",
    "dist_systematic_exact",
    "dist_systematic_exact_banked",
    "dist_systematic_local",
    "dist_systematic_local_banked",
    "make_dist_bank_step",
    "make_dist_pf_step",
]

SCHEMES = ("exact", "local")


@dataclasses.dataclass(frozen=True)
class DistributedConfig:
    mesh: Any  # jax.sharding.Mesh
    axis: str | tuple[str, ...] = "data"  # particle-sharding mesh axes
    scheme: str = "exact"  # or "local"
    exchange_every: int = 4  # ring-exchange period for the local scheme
    exchange_frac: float = 0.25  # fraction of the local slice exchanged
    bank_axis: str | None = None  # FilterBank slot axis (mesh x bank)

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise KeyError(
                f"unknown resampling scheme {self.scheme!r}; "
                f"have {sorted(SCHEMES)}"
            )
        # A zero/negative period or an out-of-range fraction would silently
        # disable the RNA weight-variance exchange — reject it up front.
        if self.exchange_every < 1:
            raise ValueError(
                f"exchange_every must be >= 1, got {self.exchange_every}"
            )
        if not 0.0 < self.exchange_frac <= 1.0:
            raise ValueError(
                f"exchange_frac must be in (0, 1], got {self.exchange_frac}"
            )
        if self.bank_axis is not None and self.bank_axis in self.axes:
            raise ValueError(
                f"bank_axis {self.bank_axis!r} collides with particle "
                f"axes {self.axes}; slots and particles must shard over "
                "disjoint mesh axes"
            )

    @property
    def axes(self) -> tuple[str, ...]:
        return (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)

    @property
    def num_shards(self) -> int:
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        n = 1
        for a in self.axes:
            n *= shape[a]
        return n


def _axis_index(axes: tuple[str, ...]) -> jax.Array:
    """Linearized device index along a tuple of mesh axes."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axis_size(axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def _local_u0(keys: jax.Array, d: jax.Array) -> jax.Array:
    """Per-slot shard-local systematic offsets: ``uniform(fold_in(key, d))``
    per row.  The ONE derivation — both the composed local resample and
    the fused-finalize path draw from it, so their ancestors stay bitwise
    interchangeable."""
    return jax.vmap(
        lambda k: jax.random.uniform(
            jax.random.fold_in(k, d), (), jnp.float32
        )
    )(keys)


def dist_normalize(log_w: jax.Array, axes: tuple[str, ...], accum_dtype):
    """Per-shard log-weights -> (normalized weights, global lse, global max).

    Runs inside shard_map.  Traffic: one pmax + one psum of a scalar.
    """
    x = log_w.astype(accum_dtype)
    m_loc = jnp.max(x)
    m = jax.lax.pmax(m_loc, axes)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = jax.lax.psum(jnp.sum(jnp.exp(x - m_safe)), axes)
    lse = jnp.where(jnp.isfinite(m), m_safe + jnp.log(s), m)
    w = jnp.exp(x - jnp.where(jnp.isfinite(lse), lse, 0.0))
    return w.astype(log_w.dtype), lse, m


def dist_systematic_exact(
    u0: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
    gather: Any = None,
) -> Any:
    """Global systematic resampling inside shard_map.

    weights: (P_loc,) globally normalized (psum over shards == 1).
    ``gather`` overrides ancestor selection on the all-gathered particles
    (``SMCSpec.gather`` — pytrees whose particle axis is not leading
    everywhere); default takes along axis 0.
    Returns resampled particles with the same local shapes.
    """
    p_loc = weights.shape[0]
    n_dev = _axis_size(axes)
    n_total = p_loc * n_dev
    d = _axis_index(axes)

    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32)
    sums = jax.lax.all_gather(local_sum, axes, tiled=False).reshape(-1)
    offset = jnp.sum(jnp.where(jnp.arange(n_dev) < d, sums, 0.0))
    cdf = offset + jnp.cumsum(w32)  # this shard's slice of the global CDF
    total = jnp.sum(sums)

    # Output positions owned by this device: g in [d*p_loc, (d+1)*p_loc).
    # IEEE fp32 reciprocal — same bits as the banked/ragged grids.
    g = d * p_loc + jnp.arange(p_loc, dtype=jnp.float32)
    inv = jnp.float32(1.0) / jnp.float32(n_total)
    u = (g + u0.astype(jnp.float32)) * inv * total

    cdf_all = jax.lax.all_gather(cdf, axes, tiled=True)  # (P_total,)
    anc = jnp.clip(
        jnp.searchsorted(cdf_all, u, side="right"), 0, n_total - 1
    ).astype(jnp.int32)

    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axes, tiled=True), particles
    )
    if gather is not None:
        return gather(gathered, anc)
    return jax.tree.map(lambda x: jnp.take(x, anc, axis=0), gathered)


def dist_systematic_local(
    key: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
    *,
    step: jax.Array,
    exchange_every: int,
    exchange_frac: float,
    out_log_w_dtype,
    gather: Any = None,
) -> tuple[Any, jax.Array]:
    """RNA-style local resampling with periodic weighted ring exchange.

    weights: globally normalized local weights.  Offspring inherit the
    shard's mass share: log_w = log(local_sum) - log(p_loc).  Returns
    (particles, per-particle log-weights) — weights travel with exchanged
    particles so no separate shard-offset state is needed.
    """
    p_loc = weights.shape[0]
    d = _axis_index(axes)
    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32)

    u0 = jax.random.uniform(jax.random.fold_in(key, d), (), jnp.float32)
    cdf = jnp.cumsum(w32)
    cdf = cdf / cdf[-1]
    u = (jnp.arange(p_loc, dtype=jnp.float32) + u0) * (
        jnp.float32(1.0) / jnp.float32(p_loc)
    )
    anc = jnp.clip(
        jnp.searchsorted(cdf, u, side="right"), 0, p_loc - 1
    ).astype(jnp.int32)
    if gather is not None:
        res = gather(particles, anc)
    else:
        res = jax.tree.map(lambda x: jnp.take(x, anc, axis=0), particles)
    log_w = jnp.full(
        (p_loc,), 0.0, jnp.float32
    ) + (jnp.log(local_sum) - jnp.log(jnp.float32(p_loc)))

    # Periodic ring exchange of the leading block, weights included.
    n_dev = _axis_size(axes)
    k = max(1, int(p_loc * exchange_frac))
    ring_axis = axes[-1]
    n_ring = compat.axis_size(ring_axis)
    perm = [(i, (i + 1) % n_ring) for i in range(n_ring)]

    def _exchange(args):
        ps, lw = args

        def swap(x):
            recv = jax.lax.ppermute(x[:k], ring_axis, perm)
            return jnp.concatenate([recv, x[k:]], axis=0)

        return jax.tree.map(swap, ps), swap(lw)

    do_x = jnp.logical_and(
        n_dev > 1, (step % exchange_every) == (exchange_every - 1)
    )
    res, log_w = jax.lax.cond(do_x, _exchange, lambda a: a, (res, log_w))
    return res, log_w.astype(out_log_w_dtype)


# ---------------------------------------------------------------------------
# Banked forms: a FilterBank row-batch of B_loc slots per device.  Row
# semantics are identical to the single-filter functions above (same
# reduction order along the particle axis, same key derivations per row);
# collectives carry (B_loc,) vectors instead of scalars, so the whole local
# bank merges in one launch.


def dist_lse_merge(
    m_loc: jax.Array,
    lse_loc: jax.Array,
    axes: tuple[str, ...],
    accum_dtype,
):
    """Merge per-shard online-LSE states: (m_loc, lse_loc) (B_loc,) each ->
    (lse, m) with one ``pmax`` + one ``psum`` per row.

    The ONE merge — :func:`dist_lse_banked`'s kernel branch and the fused
    full-step head (``fused_step_stats`` in :func:`make_dist_bank_step`)
    both fold their shard states through it, so the two paths stay bitwise
    interchangeable.  ``exp(lse_loc - m_safe)`` is the shard's exp-sum
    rebased to the global max (0 where the shard saw only -inf).
    """
    m_loc = m_loc.astype(accum_dtype)
    lse_loc = lse_loc.astype(accum_dtype)
    m = jax.lax.pmax(m_loc, axes)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = jax.lax.psum(jnp.exp(lse_loc - m_safe), axes)
    lse = jnp.where(jnp.isfinite(m), m_safe + jnp.log(s), m)
    return lse, m


def dist_lse_banked(
    log_w: jax.Array,
    axes: tuple[str, ...],
    accum_dtype,
    local_stats: Any = None,
    local_stats_masked: Any = None,
    n_loc: jax.Array | None = None,
):
    """Per-slot log-weights (B_loc, P_loc) -> merged (lse (B_loc,), max).

    The one-``pmax``+``psum``-per-row online-LSE merge, split out of
    :func:`dist_normalize_banked` so the fused shard-local finalize kernel
    can consume the merged LSE without the separate weight pass.
    ``local_stats`` optionally supplies the shard-local reduction as a
    fused kernel — ``(log_w) -> (m_loc (B_loc,), lse_loc (B_loc,))`` in
    fp32 (``repro.kernels.logsumexp.ops.online_logsumexp_batched``).  On a
    ragged bank ``n_loc`` gives each row's *shard-local* active count and
    ``local_stats_masked`` the count-aware kernel
    (``online_logsumexp_masked`` — lanes past the count pinned to -inf in
    the carry); the caller still pre-masks ``log_w``, which the pure-jnp
    and dense-kernel paths rely on.
    """
    x = log_w.astype(accum_dtype)
    if local_stats_masked is not None and n_loc is not None:
        local_stats = lambda lw: local_stats_masked(lw, n_loc)  # noqa: E731
    if local_stats is None:
        m_loc = jnp.max(x, axis=-1)
        m = jax.lax.pmax(m_loc, axes)
        m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
        s = jax.lax.psum(
            jnp.sum(jnp.exp(x - m_safe[:, None]), axis=-1), axes
        )
        lse = jnp.where(jnp.isfinite(m), m_safe + jnp.log(s), m)
    else:
        m_loc, lse_loc = local_stats(log_w)
        lse, m = dist_lse_merge(m_loc, lse_loc, axes, accum_dtype)
    return lse, m


def dist_normalize_banked(
    log_w: jax.Array,
    axes: tuple[str, ...],
    accum_dtype,
    local_stats: Any = None,
    local_stats_masked: Any = None,
    n_loc: jax.Array | None = None,
):
    """Per-slot log-weights (B_loc, P_loc) -> (weights, lse (B_loc,), max).

    Runs inside shard_map; collectives span only the particle ``axes``
    (see :func:`dist_lse_banked` for the merge and the ragged contract —
    the weight output is exactly 0 past each row's count on every path).
    """
    lse, m = dist_lse_banked(
        log_w,
        axes,
        accum_dtype,
        local_stats=local_stats,
        local_stats_masked=local_stats_masked,
        n_loc=n_loc,
    )
    x = log_w.astype(accum_dtype)
    w = jnp.exp(x - jnp.where(jnp.isfinite(lse), lse, 0.0)[:, None])
    return w.astype(log_w.dtype), lse, m


def dist_systematic_exact_banked(
    u0: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
    gather: Any = None,
    particle_axes: Any = None,
    n_active: jax.Array | None = None,
) -> Any:
    """Per-slot global systematic resampling inside shard_map.

    u0: (B_loc,) per-slot offsets; weights: (B_loc, P_loc) globally
    normalized per row.  Each row all-gathers its CDF slice and particle
    states along the particle axes and selects the ancestors for this
    device's output slice — slots never exchange anything.
    ``particle_axes``: per-leaf particle-axis pytree (``SMCSpec``
    convention, bank axis excluded); None means axis 0 after the bank dim.
    ``n_active``: (B_loc,) per-slot *global* active counts (ragged bank) —
    the u-grid spans the active count, so only output positions below it
    receive meaningful draws (the caller pins the rest to -inf weight);
    inactive lanes carry weight 0, so the gathered CDF is flat past each
    slot's prefix and no padding lane is ever selected.
    """
    nb, p_loc = weights.shape
    n_dev = _axis_size(axes)
    n_total = p_loc * n_dev
    d = _axis_index(axes)

    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32, axis=-1)  # (B_loc,)
    sums = jax.lax.all_gather(local_sum, axes, tiled=False)  # (n_dev, B_loc)
    offset = jnp.sum(
        jnp.where((jnp.arange(n_dev) < d)[:, None], sums, 0.0), axis=0
    )
    cdf = offset[:, None] + jnp.cumsum(w32, axis=-1)
    total = jnp.sum(sums, axis=0)

    g = d * p_loc + jnp.arange(p_loc, dtype=jnp.float32)
    if n_active is None:
        # IEEE fp32 reciprocal: folds bit-identically to the ragged path's
        # runtime division, so a full-width ragged bank matches this dense
        # grid exactly.
        inv = jnp.float32(1.0) / jnp.float32(n_total)
        inv = jnp.broadcast_to(inv, total.shape)
    else:
        inv = jnp.float32(1.0) / jnp.maximum(n_active, 1).astype(jnp.float32)
    u = (
        (g[None, :] + u0.astype(jnp.float32)[:, None])
        * inv[:, None]
        * total[:, None]
    )

    cdf_all = jax.lax.all_gather(cdf, axes, tiled=True, axis=1)
    anc = jax.vmap(
        lambda c, uu: jnp.searchsorted(c, uu, side="right")
    )(cdf_all, u)
    anc = jnp.clip(anc, 0, n_total - 1).astype(jnp.int32)

    if particle_axes is None:
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axes, tiled=True, axis=1),
            particles,
        )
    else:
        gathered = jax.tree.map(
            lambda x, ax: jax.lax.all_gather(
                x, axes, tiled=True, axis=1 + ax
            ),
            particles,
            particle_axes,
        )
    if gather is not None:
        return jax.vmap(gather)(gathered, anc)
    return jax.tree.map(
        lambda x: jax.vmap(lambda row, a: jnp.take(row, a, axis=0))(x, anc),
        gathered,
    )


def dist_systematic_local_banked(
    keys: jax.Array,
    weights: jax.Array,
    particles: Any,
    axes: tuple[str, ...],
    *,
    step: jax.Array,
    exchange_every: int,
    exchange_frac: float,
    out_log_w_dtype,
    gather: Any = None,
    local_resample: Any = None,
    particle_axes: Any = None,
    n_active: jax.Array | None = None,
    local_resample_masked: Any = None,
    anc: jax.Array | None = None,
) -> tuple[Any, jax.Array]:
    """Per-slot RNA local resampling with per-slot-gated ring exchange.

    ``anc``: optional precomputed shard-local ancestors (B_loc, P_loc) —
    the fused-finalize path draws them inside the epilogue kernel (same u0
    derivation from the same keys), so this function skips its own
    CDF/search and only applies the gather, RNA weights, and exchange.

    keys: (B_loc,) per-slot keys; weights: (B_loc, P_loc) globally
    normalized per row; step: (B_loc,) per-slot step counters.  The
    exchange gate is per slot (slots admitted at different ticks disagree
    on parity), so the ``ppermute`` runs unconditionally and each row
    selects between exchanged and kept blocks — O(exchange_frac·P/D·state)
    collective bytes every step instead of every ``exchange_every`` steps,
    the price of recompile-free mid-flight admission.  ``local_resample``
    optionally supplies the shard-local systematic inverse as a fused
    kernel: ``(u0 (B_loc,), weights) -> ancestors (B_loc, P_loc)``.

    Ragged rows (``n_active``: per-slot *global* counts): each shard
    resamples its own *active slice* — ``n_loc = clip(n_active - d·P_loc,
    0, P_loc)`` lanes — and its offspring inherit ``log(local_sum) -
    log(n_loc)``; fully inactive shards contribute zero mass and -inf
    rows.  The ring exchange is additionally gated on the slot being
    full-width: a partial slot's head block could land on a neighbour's
    padding lanes, which must stay at -inf weight to keep the mask
    consistent, and silently dropping exchanged mass would bias the
    estimator — so partial slots keep RNA's unbiasedness and skip only the
    variance-control mixing.  ``local_resample_masked`` is the count-aware
    kernel form ``(u0, weights, n_loc) -> ancestors``.
    """
    nb, p_loc = weights.shape
    d = _axis_index(axes)
    w32 = weights.astype(jnp.float32)
    local_sum = jnp.sum(w32, axis=-1)  # (B_loc,)

    u0 = None
    if anc is None:
        u0 = _local_u0(keys, d)
    if n_active is None:
        n_loc = None
        if anc is not None:
            pass
        elif local_resample is not None:
            anc = local_resample(u0, weights)
        else:
            cdf = jnp.cumsum(w32, axis=-1)
            cdf = cdf / cdf[:, -1:]
            u = (
                jnp.arange(p_loc, dtype=jnp.float32)[None, :] + u0[:, None]
            ) * (jnp.float32(1.0) / jnp.float32(p_loc))
            anc = jax.vmap(
                lambda c, uu: jnp.searchsorted(c, uu, side="right")
            )(cdf, u)
            anc = jnp.clip(anc, 0, p_loc - 1).astype(jnp.int32)
        n_loc_f = jnp.float32(p_loc)
        log_w = jnp.broadcast_to(
            (jnp.log(local_sum) - jnp.log(n_loc_f))[:, None],
            (nb, p_loc),
        )
    else:
        n_loc = jnp.clip(n_active - d * p_loc, 0, p_loc)  # (B_loc,)
        if anc is not None:
            pass
        elif local_resample_masked is not None:
            anc = local_resample_masked(u0, weights, n_loc)
        else:
            # Same unguarded division as the dense branch: a zero-mass
            # slice (all weight on other shards, or n_loc == 0) yields NaN
            # cdf and deterministic clipped garbage ancestors in *both*
            # paths — those offspring carry -inf RNA weight either way, and
            # a full-width ragged bank stays bit-identical to the dense
            # one.
            cdf = jnp.cumsum(w32, axis=-1)
            cdf = cdf / cdf[:, -1:]
            u = (
                jnp.arange(p_loc, dtype=jnp.float32)[None, :] + u0[:, None]
            ) * (
                jnp.float32(1.0)
                / jnp.maximum(n_loc, 1).astype(jnp.float32)
            )[:, None]
            anc = jax.vmap(
                lambda c, uu: jnp.searchsorted(c, uu, side="right")
            )(cdf, u)
            anc = jnp.clip(anc, 0, p_loc - 1).astype(jnp.int32)
        n_loc_f = jnp.maximum(n_loc, 1).astype(jnp.float32)
        log_w = jnp.where(
            jnp.arange(p_loc)[None, :] < n_loc[:, None],
            (jnp.log(local_sum) - jnp.log(n_loc_f))[:, None],
            jnp.float32(-jnp.inf),
        )
    if gather is not None:
        res = jax.vmap(gather)(particles, anc)
    else:
        res = jax.tree.map(
            lambda x: jax.vmap(lambda row, a: jnp.take(row, a, axis=0))(
                x, anc
            ),
            particles,
        )

    n_dev = _axis_size(axes)
    k = max(1, int(p_loc * exchange_frac))
    ring_axis = axes[-1]
    n_ring = compat.axis_size(ring_axis)
    perm = [(i, (i + 1) % n_ring) for i in range(n_ring)]
    do_x = jnp.logical_and(
        n_dev > 1, (step % exchange_every) == (exchange_every - 1)
    )  # (B_loc,) — per-slot gate
    if n_active is not None:
        # Only full-width slots mix (see docstring).
        do_x = jnp.logical_and(do_x, n_active == p_loc * n_dev)

    def swap(x, ax=0):
        pax = 1 + ax  # bank dim leads every leaf
        head = jax.lax.slice_in_dim(x, 0, k, axis=pax)
        tail = jax.lax.slice_in_dim(x, k, None, axis=pax)
        recv = jax.lax.ppermute(head, ring_axis, perm)
        swapped = jnp.concatenate([recv, tail], axis=pax)
        sel = do_x.reshape((nb,) + (1,) * (x.ndim - 1))
        return jnp.where(sel, swapped, x)

    if particle_axes is None:
        res = jax.tree.map(swap, res)
    else:
        res = jax.tree.map(swap, res, particle_axes)
    log_w = swap(log_w)
    return res, log_w.astype(out_log_w_dtype)


def make_dist_pf_step(
    spec,
    policy: PrecisionPolicy,
    cfg: DistributedConfig,
):
    """Build a shard_map'd PF step.

    Low-level: ``repro.core.engine.ParticleFilter`` wraps this behind the
    uniform ``step(state, obs, key)`` API when ``FilterConfig.mesh`` is set.

    Signature of the returned fn:
        (particles, log_w, step, obs, key) ->
        (particles, log_w, step+1, estimate, ess, lse, max_log_w)
    ``particles`` leaves and ``log_w`` are sharded on ``cfg.axes``; the
    observation and key are replicated.
    """
    axes = cfg.axes
    pspec = P(axes)

    def _step(particles, log_w, step, obs, key):
        k_prop, k_res = jax.random.split(jax.random.fold_in(key, 0))
        d = _axis_index(axes)
        particles = spec.transition(
            jax.random.fold_in(k_prop, d), particles, step
        )
        log_lik = spec.loglik(particles, obs, step).astype(
            policy.compute_dtype
        )
        log_w = log_w + log_lik
        w, lse, max_lw = dist_normalize(log_w, axes, policy.accum_dtype)

        wsum = jax.lax.psum(jnp.sum(w.astype(policy.accum_dtype)), axes)

        def _wmean(x):
            if not jnp.issubdtype(x.dtype, jnp.inexact):
                return x
            wx = w.astype(policy.accum_dtype).reshape(
                w.shape + (1,) * (x.ndim - 1)
            )
            return (
                jax.lax.psum(
                    jnp.sum(x.astype(policy.accum_dtype) * wx, axis=0), axes
                )
                / wsum
            )

        if spec.summary is not None:
            # Per-shard partial summary, psum-merged: under a mesh the
            # summary must be a weighted *sum* (linear in (w, particle)
            # pairs, like the decode spec's mean reward) so partials add.
            partial = spec.summary(particles, w.astype(policy.accum_dtype))
            estimate = jax.tree.map(
                lambda x: jax.lax.psum(x, axes), partial
            )
        else:
            estimate = jax.tree.map(_wmean, particles)
        ess = jnp.square(wsum) / jax.lax.psum(
            jnp.sum(jnp.square(w.astype(policy.accum_dtype))), axes
        )

        p_loc = log_w.shape[0]
        if cfg.scheme == "exact":
            u0 = jax.random.uniform(k_res, (), jnp.float32)
            new_particles = dist_systematic_exact(
                u0, w, particles, axes, gather=spec.gather
            )
            new_log_w = jnp.full(
                (p_loc,),
                # analysis: allow(host-log): must fold to the exact bits of
                # the dense engine's -jnp.log(float(P)) constant
                -jnp.log(float(p_loc * cfg.num_shards)),
                policy.compute_dtype,
            )
        else:
            new_particles, new_log_w = dist_systematic_local(
                k_res,
                w,
                particles,
                axes,
                step=step,
                exchange_every=cfg.exchange_every,
                exchange_frac=cfg.exchange_frac,
                out_log_w_dtype=policy.compute_dtype,
                gather=spec.gather,
            )
        return new_particles, new_log_w, step + 1, estimate, ess, lse, max_lw

    in_specs = (pspec, pspec, P(), P(), P())
    out_specs = (pspec, pspec, P(), P(), P(), P(), P())

    return compat.shard_map(
        _step,
        mesh=cfg.mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )


def make_dist_bank_step(
    spec,
    policy: PrecisionPolicy,
    cfg: DistributedConfig,
    *,
    shared_obs: bool = False,
    ragged: bool = False,
    local_stats: Any = None,
    local_stats_masked: Any = None,
    local_resample: Any = None,
    local_resample_masked: Any = None,
    fused_finalize: Any = None,
    fused_finalize_masked: Any = None,
    fused_step_stats: Any = None,
    fused_step_stats_masked: Any = None,
):
    """Build a shard_map'd FilterBank step: mesh × bank composition.

    Low-level: ``repro.core.engine.FilterBank`` wraps this behind the
    uniform ``step(state, observations, keys)`` API when
    ``FilterConfig.mesh`` is set.  Slots shard over ``cfg.bank_axis``
    and each slot's particles over ``cfg.axes``; per-slot numerics mirror
    :func:`make_dist_pf_step` row for row (a meshed B=1 bank in ``exact``
    mode is bit-comparable to the meshed single filter given the same
    keys).

    Signature of the returned fn:
        (particles (B, P, ...), log_w (B, P), step (B,), obs, keys (B,)) ->
        (particles, log_w, step+1, estimate, ess, lse, max_log_w)
    ``obs`` is replicated when ``shared_obs`` (every slot sees the same
    frame) and sharded on its leading bank axis otherwise.  ``local_stats``
    / ``local_resample`` are the backend's fused shard-local kernels (see
    :func:`dist_normalize_banked` / :func:`dist_systematic_local_banked`).

    ``ragged=True`` appends two (B,) bank-sharded inputs — per-slot global
    active counts ``n_active`` and stored uniform log-weights
    ``log_uniform`` — and masks each shard's slice of every slot to its
    active prefix: lanes at global position >= n_active[b] enter the
    online-LSE merge at -inf (weight exactly 0 — the pmax/psum collectives
    are unchanged), the exact scheme's u-grid spans the active count, and
    the local scheme resamples each shard's active sub-slice (mixing only
    full-width slots).  A full-width ragged bank is bit-identical to the
    dense step.

    ``fused_finalize`` / ``fused_finalize_masked`` supply the backend's
    shard-local fused epilogue tail for the ``local`` scheme:
    ``(log_w, lse, u0[, n_loc]) -> (weights, ancestors)``.  The merged LSE
    still comes from the one-``pmax``+``psum`` ``local_stats`` merge; the
    finalize pass then computes the shard's weights and chains the
    shard-local systematic inverse on its in-VMEM CDF, replacing the
    separate exp + ``ancestors_from_u0`` launches (same u0 derivation,
    bitwise the composed path).

    ``fused_step_stats`` / ``fused_step_stats_masked`` supply the matching
    shard-local fused *head* — ``(log_w, patches, model, policy[, n_loc])
    -> (log_w', m (B,), lse (B,))``: likelihood, prior add, and the
    online-LSE stats of ``local_stats`` in one pass over this shard's
    gathered patches (``spec.step_fusion`` describes the gather and
    model).  The head's per-shard states fold through the same
    :func:`dist_lse_merge` as the ``local_stats`` branch and its
    log-weights feed the ``fused_finalize`` tail, so the shard's weight
    array streams from patches to ancestors with one merge in between —
    bitwise the composed likelihood → stats → finalize chain.  Only used
    when the matching ``fused_finalize`` form is also present (the caller
    gates on it).
    """
    if cfg.bank_axis is None:
        raise ValueError("make_dist_bank_step needs cfg.bank_axis set")
    axes = cfg.axes
    bspec = P(cfg.bank_axis)
    pspec = P(cfg.bank_axis, axes)
    paxes = spec.particle_axes
    if paxes is not None and (spec.summary is None or spec.gather is None):
        raise ValueError(
            "a meshed FilterBank over a spec with non-leading particle "
            "axes (particle_axes set) needs an explicit summary AND "
            "gather: the default weighted-mean estimate and the default "
            "ancestor take assume a leading particle axis on every leaf"
        )
    if paxes is None:
        part_specs: Any = pspec  # prefix broadcast over the pytree
    else:
        # Per-leaf placement: bank axis leads, particle axes land on the
        # leaf's own particle dimension (LM caches are not leading-axis).
        part_specs = jax.tree.map(
            lambda ax: P(cfg.bank_axis, *([None] * ax), axes), paxes
        )
    obs_ax = None if shared_obs else 0
    adt = policy.accum_dtype

    def _step(particles, log_w, step, obs, keys, n_active=None, log_uni=None):
        # Per-slot key chain — the single-filter derivation applied row by
        # row, so a B=1 bank consumes keys exactly like ParticleFilter.
        split = jax.vmap(
            lambda k: jax.random.split(jax.random.fold_in(k, 0))
        )(keys)
        k_prop, k_res = split[:, 0], split[:, 1]
        d = _axis_index(axes)
        prop_keys = jax.vmap(lambda k: jax.random.fold_in(k, d))(k_prop)
        particles = jax.vmap(spec.transition)(prop_keys, particles, step)
        if n_active is None:
            active = None
            n_loc = None
        else:
            # This shard owns global lanes [d*P_loc, (d+1)*P_loc); mask its
            # slice of every slot to the slot's active prefix (which is a
            # *local* prefix of n_loc lanes — particles shard contiguously).
            p_loc_ = log_w.shape[-1]
            gpos = d * p_loc_ + jnp.arange(p_loc_)
            active = gpos[None, :] < n_active[:, None]
            n_loc = jnp.clip(n_active - d * p_loc_, 0, p_loc_)
        finalize = fused_finalize_masked if n_active is not None else (
            fused_finalize
        )
        head = fused_step_stats_masked if n_active is not None else (
            fused_step_stats
        )
        anc = None
        if cfg.scheme == "local" and finalize is not None and head is not None:
            # Fused shard-local head + tail: one pass scores this shard's
            # gathered patches, adds the carried log-weights, and emits the
            # online-LSE stats; dist_lse_merge folds the shard states with
            # the same pmax+psum as the composed local_stats branch; the
            # finalize pass then computes the shard's weights and the RNA
            # scheme's shard-local systematic ancestors (same _local_u0
            # derivation as dist_systematic_local_banked).  The shard's
            # log-weight array never makes a separate likelihood round
            # trip — bitwise the composed chain below.
            fstep = spec.step_fusion
            patches = jax.vmap(fstep.gather, in_axes=(0, obs_ax, 0))(
                particles, obs, step
            )
            if n_active is None:
                log_w, m_loc, lse_loc = head(
                    log_w, patches, fstep.model, policy
                )
            else:
                log_w, m_loc, lse_loc = head(
                    log_w, patches, fstep.model, policy, n_loc
                )
            lse, max_lw = dist_lse_merge(m_loc, lse_loc, axes, adt)
            u0 = _local_u0(k_res, d)
            if n_active is None:
                w, anc = fused_finalize(log_w, lse, u0)
            else:
                w, anc = fused_finalize_masked(log_w, lse, u0, n_loc)
        else:
            log_lik = jax.vmap(spec.loglik, in_axes=(0, obs_ax, 0))(
                particles, obs, step
            ).astype(policy.compute_dtype)
            if n_active is None:
                log_w = log_w + log_lik
            else:
                log_w = jnp.where(
                    active,
                    log_w + log_lik,
                    jnp.asarray(-jnp.inf, policy.compute_dtype),
                )
            if cfg.scheme == "local" and finalize is not None:
                # Fused shard-local epilogue tail: merge the LSE stats,
                # then one pass computes this shard's weights *and* the
                # RNA scheme's shard-local systematic ancestors (same
                # _local_u0 derivation as dist_systematic_local_banked).
                lse, max_lw = dist_lse_banked(
                    log_w, axes, adt,
                    local_stats=local_stats,
                    local_stats_masked=local_stats_masked,
                    n_loc=n_loc,
                )
                u0 = _local_u0(k_res, d)
                if n_active is None:
                    w, anc = fused_finalize(log_w, lse, u0)
                else:
                    w, anc = fused_finalize_masked(log_w, lse, u0, n_loc)
            else:
                w, lse, max_lw = dist_normalize_banked(
                    log_w, axes, adt,
                    local_stats=local_stats,
                    local_stats_masked=local_stats_masked,
                    n_loc=n_loc,
                )

        w_acc = w.astype(adt)
        wsum = jax.lax.psum(jnp.sum(w_acc, axis=-1), axes)  # (B_loc,)

        def _wmean(x):
            if not jnp.issubdtype(x.dtype, jnp.inexact):
                return x
            wx = w_acc.reshape(w_acc.shape + (1,) * (x.ndim - 2))
            num = jax.lax.psum(
                jnp.sum(x.astype(adt) * wx, axis=1), axes
            )
            return num / wsum.reshape((-1,) + (1,) * (x.ndim - 2))

        if spec.summary is not None:
            # Per-shard, per-slot partial summaries psum-merge over the
            # particle axes (the summary must be a weighted sum — see
            # make_dist_pf_step).
            partial = jax.vmap(spec.summary)(particles, w_acc)
            estimate = jax.tree.map(
                lambda x: jax.lax.psum(x, axes), partial
            )
        else:
            estimate = jax.tree.map(_wmean, particles)
        ess = jnp.square(wsum) / jax.lax.psum(
            jnp.sum(jnp.square(w_acc), axis=-1), axes
        )

        p_loc = log_w.shape[-1]
        if cfg.scheme == "exact":
            u0 = jax.vmap(
                lambda k: jax.random.uniform(k, (), jnp.float32)
            )(k_res)
            new_particles = dist_systematic_exact_banked(
                u0, w, particles, axes,
                gather=spec.gather,
                particle_axes=paxes,
                n_active=n_active,
            )
            if n_active is None:
                new_log_w = jnp.full_like(
                    # analysis: allow(host-log): must fold to the exact bits
                    # of the dense engine's -jnp.log(float(P)) constant
                    log_w, -jnp.log(float(p_loc * cfg.num_shards))
                )
            else:
                # The stored per-slot uniform (-log(n_active), same bits as
                # the dense Python constant for full-width slots).
                new_log_w = jnp.where(
                    active,
                    jnp.broadcast_to(
                        log_uni[:, None], log_w.shape
                    ).astype(log_w.dtype),
                    jnp.asarray(-jnp.inf, log_w.dtype),
                )
        else:
            new_particles, new_log_w = dist_systematic_local_banked(
                k_res,
                w,
                particles,
                axes,
                step=step,
                exchange_every=cfg.exchange_every,
                exchange_frac=cfg.exchange_frac,
                out_log_w_dtype=policy.compute_dtype,
                gather=spec.gather,
                local_resample=local_resample,
                particle_axes=paxes,
                n_active=n_active,
                local_resample_masked=local_resample_masked,
                anc=anc,
            )
        return new_particles, new_log_w, step + 1, estimate, ess, lse, max_lw

    in_specs = (
        part_specs,
        pspec,
        bspec,
        P() if shared_obs else bspec,
        bspec,
    )
    if ragged:
        in_specs = in_specs + (bspec, bspec)
    out_specs = (part_specs, pspec, bspec, bspec, bspec, bspec, bspec)

    return compat.shard_map(
        _step,
        mesh=cfg.mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )
