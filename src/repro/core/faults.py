"""Deterministic fault injection for the serving stack.

Every fp16 failure mode the paper's algorithmic changes defend against —
and every serving failure the fault-tolerance layer must contain — is a
*production surprise* unless it can be produced on demand: this module
turns each one into a seeded, reproducible event.  The injector's whole
schedule derives from the run key, so one seed is one exact chaos
scenario (which fault, which tick, which slot), re-runnable bit for bit;
and with the injector absent the serve path executes **zero** extra
device or host work (the scheduler's hooks are all ``if injector is not
None`` — disabled chaos is bitwise the plain serve path, CI-checked).

Fault classes (``ChaosConfig.classes``):

- ``nan_lanes``    — NaN-poison every inexact leaf of one busy slot's
  particle rows (token caches included): the next bank step's
  likelihood turns non-finite and the fused epilogue's stats surface it
  as a non-finite ESS — the paper's "one bad half-precision value
  silently corrupts the posterior" scenario.
- ``inf_weights``  — overwrite one busy slot's log-weight row with +Inf:
  normalize produces Inf-Inf = NaN weights, the weight-side twin.
- ``drop_upload``  — swallow one admission's slot upload: the scheduler
  believes the request was admitted, the device still holds the
  previous occupant's state (the health monitor's step-progress
  integrity rule is what catches this).
- ``fail_step``    — one lane's bank-step dispatch "fails" for the first
  ``fail_attempts`` attempts on its tick (the scheduler's bounded-
  backoff retry path on the non-donated entry point must absorb it).
- ``delay_step``   — one lane's step is delayed ``delay_ms`` on the host
  timeline, tripping the wall-clock step watchdog.

The injector is intentionally host-side and numpy-deterministic: faults
are injected *between* jitted calls (state surgery via ``.at[slot]``),
never inside a kernel, so the chaos harness cannot perturb compiled
programs or trace caches.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ChaosConfig",
    "Fault",
    "FaultInjector",
    "poison_particle_rows",
    "poison_weight_row",
]

FAULT_CLASSES = (
    "nan_lanes",
    "inf_weights",
    "drop_upload",
    "fail_step",
    "delay_step",
)

# State-surgery faults target a busy slot; step faults target a lane.
_STATE_FAULTS = ("nan_lanes", "inf_weights")
_STEP_FAULTS = ("fail_step", "delay_step")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Shape of a deterministic fault schedule.

    classes:       fault classes to cycle through, in order.
    rounds:        how many times the class cycle repeats.
    start_tick:    first injection tick (leave a few healthy ticks so
                   rollback snapshots exist before the first fault).
    every:         ticks between consecutive injections — spacing them
                   out lets each recovery complete (and be measured)
                   before the next fault lands.
    fail_attempts: consecutive failing dispatch attempts per
                   ``fail_step`` fault; keep <= the scheduler's
                   ``max_step_retries`` for a recoverable scenario.
    delay_ms:      host-side delay per ``delay_step`` fault; pair with a
                   smaller ``step_timeout_ms`` so the watchdog fires.
    """

    classes: tuple[str, ...] = FAULT_CLASSES
    rounds: int = 1
    start_tick: int = 2
    every: int = 3
    fail_attempts: int = 1
    delay_ms: float = 25.0

    def __post_init__(self) -> None:
        bad = [c for c in self.classes if c not in FAULT_CLASSES]
        if bad or not self.classes:
            raise ValueError(
                f"unknown fault classes {bad}; choose from {FAULT_CLASSES}"
            )
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.start_tick < 0:
            raise ValueError(
                f"start_tick must be >= 0, got {self.start_tick}"
            )
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.fail_attempts < 1:
            raise ValueError(
                f"fail_attempts must be >= 1, got {self.fail_attempts}"
            )
        if self.delay_ms < 0:
            raise ValueError(f"delay_ms must be >= 0, got {self.delay_ms}")


@dataclasses.dataclass
class Fault:
    """One scheduled fault.  ``tick`` is the *earliest* injection tick;
    state faults needing a busy slot defer tick by tick until one exists.
    ``applied_tick``/``slot`` record where it actually landed."""

    index: int
    kind: str
    tick: int
    preferred: int  # rng-chosen slot (state faults) or lane (step faults)
    applied_tick: int | None = None
    slot: int | None = None


def _seed_from_key(key) -> int:
    """A host integer seed derived from a jax PRNG key (or passed through
    when already an int) — the whole schedule hangs off the run key."""
    if isinstance(key, (int, np.integer)):
        return int(key)
    return int(
        jax.random.randint(
            jax.random.fold_in(key, 0x5EED),
            (),
            0,
            np.iinfo(np.int32).max,
        )
    )


class FaultInjector:
    """Seeded fault schedule + the scheduler's injection hooks.

    Construction fixes the entire schedule: ``rounds`` passes over
    ``config.classes`` at ticks ``start_tick + i * every``, each fault's
    preferred slot/lane drawn from one ``numpy`` generator seeded by the
    run key.  The serve loop then calls:

    - :meth:`state_faults` once per tick → due state-surgery faults; it
      targets each at a busy slot (:meth:`target_slot`) and applies the
      poison helpers below.
    - :meth:`take_drop_upload` at each admission → swallow this upload?
    - :meth:`step_fails` / :meth:`step_delay_ms` around each lane step
      dispatch.

    ``log`` accumulates every applied fault (kind, scheduled tick,
    applied tick, slot/lane) — the ground truth ``benchmarks/chaos.py``
    joins against health recoveries for per-class recovery latency.
    """

    def __init__(self, config: ChaosConfig, key, *, num_slots: int,
                 num_lanes: int = 1):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if num_lanes < 1:
            raise ValueError(f"num_lanes must be >= 1, got {num_lanes}")
        self.config = config
        self.num_slots = num_slots
        self.num_lanes = num_lanes
        self.seed = _seed_from_key(key)
        rng = np.random.default_rng(self.seed)
        self.schedule: list[Fault] = []
        i = 0
        for _ in range(config.rounds):
            for kind in config.classes:
                space = num_lanes if kind in _STEP_FAULTS else num_slots
                self.schedule.append(
                    Fault(
                        index=i,
                        kind=kind,
                        tick=config.start_tick + i * config.every,
                        preferred=int(rng.integers(space)),
                    )
                )
                i += 1
        self.log: list[dict] = []
        # fail_step bookkeeping: (tick, lane) -> attempts already failed.
        self._failing: dict[tuple[int, int], int] = {}

    # -- schedule queries -----------------------------------------------

    def _due(self, tick: int, kinds: tuple[str, ...]) -> list[Fault]:
        return [
            f
            for f in self.schedule
            if f.applied_tick is None and f.tick <= tick and f.kind in kinds
        ]

    def state_faults(self, tick: int) -> list[Fault]:
        """Due-and-unapplied nan_lanes / inf_weights faults."""
        return self._due(tick, _STATE_FAULTS)

    def target_slot(self, fault: Fault, busy: np.ndarray) -> int | None:
        """Deterministic busy-slot target: the first busy slot at or
        after the fault's preferred index (wrapping).  None defers the
        fault to the next tick (no busy slot yet)."""
        busy = np.asarray(busy, bool)
        if not busy.any():
            return None
        order = (np.arange(self.num_slots) + fault.preferred) % (
            self.num_slots
        )
        for s in order:
            if busy[s]:
                return int(s)
        return None

    def applied(self, fault: Fault, tick: int, slot: int | None) -> None:
        fault.applied_tick = tick
        fault.slot = slot
        self.log.append(
            {
                "index": fault.index,
                "kind": fault.kind,
                "scheduled_tick": fault.tick,
                "tick": tick,
                "slot": slot,
            }
        )

    # -- admission hook -------------------------------------------------

    def take_drop_upload(self, tick: int) -> Fault | None:
        """Consume one due drop_upload fault (the admission this returns
        for must skip its device upload)."""
        due = self._due(tick, ("drop_upload",))
        return due[0] if due else None

    # -- step hooks -----------------------------------------------------

    def step_fails(self, tick: int, lane: int, attempt: int) -> bool:
        """Does this dispatch attempt of this lane's step fail?

        A due fail_step fault targeting ``lane`` fails attempts
        ``0..fail_attempts-1`` on its tick; the retry after that
        succeeds.  The fault is marked applied on its first failure.
        """
        key = (tick, lane)
        if key in self._failing:
            left = self._failing[key]
            if left > 0:
                self._failing[key] = left - 1
                return True
            return False
        for f in self._due(tick, ("fail_step",)):
            if f.preferred % self.num_lanes == lane:
                self.applied(f, tick, lane)
                self._failing[key] = self.config.fail_attempts - 1
                return True
        return False

    def step_delay_ms(self, tick: int, lane: int) -> float:
        """Host delay to add to this lane's step on this tick (0 = none)."""
        for f in self._due(tick, ("delay_step",)):
            if f.preferred % self.num_lanes == lane:
                self.applied(f, tick, lane)
                return self.config.delay_ms
        return 0.0

    # -- reporting ------------------------------------------------------

    @property
    def exhausted(self) -> bool:
        return all(f.applied_tick is not None for f in self.schedule)

    @property
    def stats(self) -> dict:
        return {
            "seed": self.seed,
            "scheduled": len(self.schedule),
            "applied": len(self.log),
            "log": list(self.log),
        }


# -- state surgery ------------------------------------------------------


def poison_particle_rows(state, slot, value: float = float("nan")):
    """Overwrite every inexact leaf of one slot's particle rows (caches
    included) with ``value`` — the NaN-lane fault.  Integer leaves
    (tokens, sequence buffers) are left alone: the corruption models a
    numeric blow-up, not memory scribbling."""
    slot = jnp.asarray(slot, jnp.int32)

    def hit(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        row = jnp.full(x.shape[1:], value, x.dtype)
        return x.at[slot].set(row)

    return state._replace(particles=jax.tree.map(hit, state.particles))


def poison_weight_row(state, slot, value: float = float("inf")):
    """Overwrite one slot's log-weight row with ``value`` (+Inf by
    default) — the Inf-weight fault.  The active-lane prefix is enough
    to corrupt the row; padding lanes keep their -inf mask so the fault
    tests the *weight pipeline's* containment, not the mask's."""
    slot = jnp.asarray(slot, jnp.int32)
    lw = state.log_weights
    row = lw[slot]
    if state.n_active is not None:
        lane = jnp.arange(lw.shape[-1])
        row = jnp.where(
            lane < state.n_active[slot],
            jnp.asarray(value, lw.dtype),
            row,
        )
    else:
        row = jnp.full(lw.shape[1:], value, lw.dtype)
    return state._replace(log_weights=lw.at[slot].set(row))
