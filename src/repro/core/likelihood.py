"""Rodinia-style intensity likelihood (paper Eqs. 3 & 4), multi-precision.

The observation model: a particle at integer position (r, c) is scored by the
pixel intensities at a fixed disk of offsets around it —

    naive (Eq. 3):   L = sum_j [ (I_j - BG)^2 - (I_j - FG)^2 ] / (50 * N)
    stable (Eq. 4):  L = sum_j [ ((I_j - BG) * isq)^2 - ((I_j - FG) * isq)^2 ]
                     with isq = 1/sqrt(50 * N)   (precomputed constant)

where BG=100, FG=228 are the mean background/foreground intensities and N is
the number of disk points.  In fp16 the naive running sum reaches ~1.6e6 for
a 69-point disk (inf > 65504); the stable form keeps every intermediate O(1).

The gather (image -> per-particle intensity patch) is done with XLA ``take``
— on TPU, per-particle dynamic gathers inside a kernel would serialize on
the scalar core, so we deliberately keep the gather in XLA and hand the
Pallas kernel (``repro.kernels.likelihood``) a dense (P, J) intensity matrix.
This is the hardware adaptation of the paper's pixel-parallel CUDA kernel.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy
from repro.kernels.common import pairwise_sum

__all__ = [
    "IntensityModel",
    "disk_offsets",
    "gather_patches",
    "intensity_loglik",
]

BACKGROUND = 100.0
FOREGROUND = 228.0


def disk_offsets(radius: int) -> jnp.ndarray:
    """Integer (dr, dc) offsets inside a disk, matching Rodinia's template.

    Computed in numpy (shape must be static — it sets kernel geometry).
    """
    import numpy as np

    r = np.arange(-radius, radius + 1)
    dr, dc = np.meshgrid(r, r, indexing="ij")
    mask = (dr**2 + dc**2) <= radius**2
    coords = np.stack([dr[mask], dc[mask]], axis=-1)
    return jnp.asarray(coords, jnp.int32)  # (J, 2)


@dataclasses.dataclass(frozen=True)
class IntensityModel:
    """Observation model: disk of ``radius`` around each particle."""

    radius: int = 4
    background: float = BACKGROUND
    foreground: float = FOREGROUND
    scale: float = 50.0

    @property
    def offsets(self) -> jnp.ndarray:
        return disk_offsets(self.radius)

    @property
    def num_points(self) -> int:
        return int(self.offsets.shape[0])


def gather_patches(
    frame: jax.Array, positions: jax.Array, offsets: jax.Array
) -> jax.Array:
    """Gather frame intensities at ``positions + offsets`` -> (P, J).

    positions: (P, 2) float (row, col); offsets: (J, 2) int.  Coordinates
    are rounded and clamped to the frame like the Rodinia implementation.
    """
    h, w = frame.shape
    pos = jnp.round(positions).astype(jnp.int32)  # (P, 2)
    rc = pos[:, None, :] + offsets[None, :, :]  # (P, J, 2)
    r = jnp.clip(rc[..., 0], 0, h - 1)
    c = jnp.clip(rc[..., 1], 0, w - 1)
    flat = r * w + c
    return jnp.take(frame.reshape(-1), flat, axis=0)  # (P, J)


def intensity_loglik(
    patches: jax.Array,
    model: IntensityModel,
    policy: PrecisionPolicy,
) -> jax.Array:
    """Per-particle log-likelihood from gathered intensities (P, J).

    Dispatches between the paper's naive Eq. 3 and stable Eq. 4 according to
    ``policy.stable_likelihood``; arithmetic in ``policy.compute_dtype`` with
    reductions in ``policy.accum_dtype`` (equal for the paper-faithful pure
    policies).
    """
    cdt, adt = policy.compute_dtype, policy.accum_dtype
    x = patches.astype(cdt)
    n = patches.shape[-1]
    bg = jnp.asarray(model.background, cdt)
    fg = jnp.asarray(model.foreground, cdt)
    if policy.stable_likelihood:
        # Eq. 4 — precomputed reciprocal sqrt constant (hoisted; the TPU
        # analogue of removing the paper's XU-pipeline rsqrt traffic).
        isq = jnp.asarray((model.scale * n) ** -0.5, cdt)
        db = (x - bg) * isq
        df = (x - fg) * isq
        terms = db * db - df * df
        # Canonical reduction order (fixed pairwise tree, shared with the
        # Pallas likelihood/step kernels): ``jnp.sum`` would let XLA pick
        # a per-context order, so jnp and pallas backends could round the
        # same patches apart; one tree keeps them bitwise-comparable.
        return pairwise_sum(terms.astype(adt)).astype(cdt)
    # Eq. 3 — divide only after summing the raw squared differences, exactly
    # the fp16-overflowing form (sum reaches ~1.6e6 for a 69-point disk on
    # foreground pixels; fp16 max is 65504).  Kept for the failure-mode tests.
    db = x - bg
    df = x - fg
    terms = db * db - df * df
    total = jnp.sum(terms.astype(adt), axis=-1)
    return (total / jnp.asarray(model.scale * n, adt)).astype(cdt)
