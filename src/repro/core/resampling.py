"""Resampling schemes for the particle filter (paper section 4, "resampling").

The paper keeps the Rodinia systematic resampler: divide [0,1) into N
partitions, take one (shared-offset) sample per partition, and walk the
cumulative weight distribution.  We implement

- ``systematic``   — one shared uniform offset (Rodinia / paper default),
- ``stratified``   — independent uniform per partition,
- ``multinomial``  — N independent categorical draws,

all expressed as (cdf build) + (vectorized ``searchsorted``), which is the
TPU-native shape of the paper's per-thread CDF walk: the sequential
conditional chain each CUDA thread runs becomes one vectorized sorted-search.
The CDF build + search also exist as a Pallas kernel
(``repro.kernels.resample``) with a blockwise fp32 carry.

Precision note: with 64k particles and fp16 weights, individual weights sit
at ~1.5e-5 — *below* the fp16 normal range (6.1e-5): a pure-fp16 CDF loses
mass to rounding.  The paper accepts this (pure-fp16 policy); our default
policies build the CDF in ``accum_dtype`` and only compare in compute dtype.
Both paths are tested.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy

__all__ = [
    "build_cdf",
    "systematic",
    "stratified",
    "multinomial",
    "RESAMPLERS",
    "register_resampler",
    "get_resampler",
    "make_resampler",
    "gather_ancestors",
]

Resampler = Callable[..., jax.Array]


def build_cdf(weights: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Inclusive prefix sum of normalized weights in accum dtype."""
    w = weights.astype(policy.accum_dtype)
    cdf = jnp.cumsum(w, axis=-1)
    # Guard the tail against rounding: the last entry must cover u < 1.
    return cdf / cdf[..., -1:]


def _search(cdf: jax.Array, u: jax.Array) -> jax.Array:
    """Vectorized CDF inversion: index of first cdf entry > u."""
    return jnp.clip(
        jnp.searchsorted(cdf, u.astype(cdf.dtype), side="right"),
        0,
        cdf.shape[-1] - 1,
    ).astype(jnp.int32)


def systematic(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """Systematic resampling: u_i = (i + u0)/N with one shared u0."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    u0 = jax.random.uniform(key, (), dtype=cdf.dtype)
    u = (jnp.arange(n_out, dtype=cdf.dtype) + u0) / n_out
    return _search(cdf, u)


def stratified(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """Stratified resampling: u_i = (i + u_i)/N, independent u_i."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    us = jax.random.uniform(key, (n_out,), dtype=cdf.dtype)
    u = (jnp.arange(n_out, dtype=cdf.dtype) + us) / n_out
    return _search(cdf, u)


def multinomial(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """N independent categorical draws (sorted-uniform CDF inversion)."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    u = jnp.sort(jax.random.uniform(key, (n_out,), dtype=cdf.dtype))
    return _search(cdf, u)


RESAMPLERS: dict[str, Resampler] = {}


def register_resampler(name: str, fn: Resampler | None = None):
    """Register ``fn`` under ``name`` (usable as a decorator).

    The registry is the extension point :class:`repro.core.engine.FilterConfig`
    dispatches on — mirroring ``precision.register_policy`` and
    ``engine.register_backend``.
    """
    if fn is None:
        return lambda f: register_resampler(name, f)
    RESAMPLERS[name] = fn
    return fn


register_resampler("systematic", systematic)
register_resampler("stratified", stratified)
register_resampler("multinomial", multinomial)


def get_resampler(name: str) -> Resampler:
    try:
        return RESAMPLERS[name]
    except KeyError:
        raise KeyError(
            f"unknown resampler {name!r}; have {sorted(RESAMPLERS)}"
        ) from None


# Pre-registry name, kept for callers of the old lookup API.
make_resampler = get_resampler


def gather_ancestors(particles, ancestors: jax.Array):
    """Select ancestor states (pytree of (P, ...) arrays) by index."""
    return jax.tree.map(lambda x: jnp.take(x, ancestors, axis=0), particles)
