"""Resampling schemes for the particle filter (paper section 4, "resampling").

The paper keeps the Rodinia systematic resampler: divide [0,1) into N
partitions, take one (shared-offset) sample per partition, and walk the
cumulative weight distribution.  We implement

- ``systematic``   — one shared uniform offset (Rodinia / paper default),
- ``stratified``   — independent uniform per partition,
- ``multinomial``  — N independent categorical draws,

all expressed as (cdf build) + (vectorized ``searchsorted``), which is the
TPU-native shape of the paper's per-thread CDF walk: the sequential
conditional chain each CUDA thread runs becomes one vectorized sorted-search.
The CDF build + search also exist as a Pallas kernel
(``repro.kernels.resample``) with a blockwise fp32 carry.

Beyond the CDF family, ``metropolis`` implements Murray's rejection-chain
resampler (arXiv:1202.6163): each offspring runs a fixed-length Metropolis
chain over ancestor indices with acceptance ratio ``w_j / w_k``.  It needs
*no* collective over the weights — no cumsum, no normalization — which is
what makes it the cheap per-filter scheme at high bank counts
(``FilterBank``): every bank row resamples independently from its own key
with zero cross-row or cross-particle reductions.  The fixed chain length
trades a small, controllable bias for that collective-freedom.

Precision note: with 64k particles and fp16 weights, individual weights sit
at ~1.5e-5 — *below* the fp16 normal range (6.1e-5): a pure-fp16 CDF loses
mass to rounding.  The paper accepts this (pure-fp16 policy); our default
policies build the CDF in ``accum_dtype`` and only compare in compute dtype.
Both paths are tested.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import stability
from repro.core.precision import PrecisionPolicy

__all__ = [
    "build_cdf",
    "reference_normalize",
    "systematic",
    "systematic_masked_banked",
    "stratified",
    "stratified_masked_banked",
    "multinomial",
    "multinomial_masked_banked",
    "metropolis",
    "metropolis_masked_banked",
    "METROPOLIS_ITERS",
    "FUSED_EPILOGUES",
    "FUSED_EPILOGUES_BANKED",
    "FUSED_EPILOGUES_MASKED",
    "FUSED_STEPS",
    "FUSED_STEPS_BANKED",
    "FUSED_STEPS_MASKED",
    "MASKED_OPT_OUTS",
    "MASKED_RESAMPLERS",
    "RESAMPLERS",
    "register_resampler",
    "get_resampler",
    "make_resampler",
    "gather_ancestors",
]

Resampler = Callable[..., jax.Array]


def build_cdf(weights: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Inclusive prefix sum of normalized weights in accum dtype."""
    w = weights.astype(policy.accum_dtype)
    cdf = jnp.cumsum(w, axis=-1)
    # Guard the tail against rounding: the last entry must cover u < 1.
    return cdf / cdf[..., -1:]


def _search(cdf: jax.Array, u: jax.Array) -> jax.Array:
    """Vectorized CDF inversion: index of first cdf entry > u."""
    return jnp.clip(
        jnp.searchsorted(cdf, u.astype(cdf.dtype), side="right"),
        0,
        cdf.shape[-1] - 1,
    ).astype(jnp.int32)


def systematic(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """Systematic resampling: u_i = (i + u0)/N with one shared u0."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    u0 = jax.random.uniform(key, (), dtype=cdf.dtype)
    u = (jnp.arange(n_out, dtype=cdf.dtype) + u0) / n_out
    return _search(cdf, u)


def systematic_masked_banked(
    keys: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    n_active: jax.Array,
) -> jax.Array:
    """Ragged-bank systematic resampling: (B,) keys, (B, P) weights, (B,)
    active counts.

    Weights on lanes >= n_active[b] must already be exactly 0 (the engine
    masks their log-weights to -inf), so the CDF is flat past the active
    prefix; the u-grid spans n_active[b] points — ``u_g = (g + u0) /
    n_active`` — making output lanes < n_active a per-row systematic draw
    over the active prefix only.  Lanes past the count probe u >= 1 and
    clip to the CDF tail; the caller pins their weights back to -inf.
    With ``n_active = P`` everywhere this is bitwise the vmapped
    :func:`systematic` (IEEE division by the same values, same searches) —
    the dense fast-path equivalence the ragged FilterBank tests assert.
    """
    p = weights.shape[-1]

    def row(key, w, n):
        cdf = _masked_cdf_row(w, policy)
        u0 = jax.random.uniform(key, (), dtype=cdf.dtype)
        u = (jnp.arange(p, dtype=cdf.dtype) + u0) / jnp.maximum(n, 1).astype(
            cdf.dtype
        )
        return _search(cdf, u)

    return jax.vmap(row)(keys, weights, n_active)


def stratified(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """Stratified resampling: u_i = (i + u_i)/N, independent u_i."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    us = jax.random.uniform(key, (n_out,), dtype=cdf.dtype)
    u = (jnp.arange(n_out, dtype=cdf.dtype) + us) / n_out
    return _search(cdf, u)


def multinomial(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
) -> jax.Array:
    """N independent categorical draws (sorted-uniform CDF inversion)."""
    n_out = num_samples or weights.shape[-1]
    cdf = build_cdf(weights, policy)
    u = jnp.sort(jax.random.uniform(key, (n_out,), dtype=cdf.dtype))
    return _search(cdf, u)


# Default chain length for ``metropolis``.  Murray's convergence bound is
# B = O(log eps / log(1 - w*)) with w* the largest normalized weight; 32
# steps put the total-variation bias below resampling noise for the weight
# profiles the tracker produces (see tests/test_resampling.py).
METROPOLIS_ITERS = 32


def metropolis(
    key: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    num_samples: int | None = None,
    *,
    iters: int = METROPOLIS_ITERS,
) -> jax.Array:
    """Murray's Metropolis resampler: fixed-iteration rejection chains.

    Offspring ``i`` starts its chain at ancestor ``i`` and runs ``iters``
    Metropolis steps: propose ``j`` uniformly, accept with probability
    ``min(1, w_j / w_k)``.  The test ``u < w_j / w_k`` is evaluated as
    ``u * w_k < w_j`` so zero weights never divide.  Weights may be
    unnormalized (the ratio cancels the normalizer) and are compared in
    ``accum_dtype`` — in pure fp16 the ratio itself is the only operation,
    so there is no CDF accumulation to lose mass (the hazard the CDF
    family has; see module docstring).

    All draws derive from ``key`` by ``fold_in`` of the chain step, so a
    banked caller (``FilterBank``) just vmaps this with per-row keys.
    """
    n = weights.shape[-1]
    n_out = num_samples or n
    w = weights.astype(policy.accum_dtype)

    def chain_step(t, anc):
        kt = jax.random.fold_in(key, t)
        k_prop, k_u = jax.random.split(kt)
        prop = jax.random.randint(k_prop, (n_out,), 0, n, dtype=jnp.int32)
        u = jax.random.uniform(k_u, (n_out,), w.dtype)
        accept = u * jnp.take(w, anc) < jnp.take(w, prop)
        return jnp.where(accept, prop, anc)

    init = jnp.arange(n_out, dtype=jnp.int32) % n
    return jax.lax.fori_loop(0, iters, chain_step, init)


def _masked_cdf_row(w, policy):
    """Per-row CDF for masked draws: zero-mass rows divide by 1, not 0
    (their draws are junk the engine pins to -inf weight); rows with mass
    divide by the same bits as :func:`build_cdf`."""
    cdf = jnp.cumsum(w.astype(policy.accum_dtype), axis=-1)
    total = cdf[..., -1:]
    return cdf / jnp.where(total > 0, total, jnp.ones_like(total))


def stratified_masked_banked(
    keys: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    n_active: jax.Array,
) -> jax.Array:
    """Ragged stratified resampling: strata span the *active* count.

    ``u_i = (i + U_i) / n_active`` — output lanes < n_active are one draw
    from each of n_active strata covering the whole active CDF (grids that
    kept the dense 1/P spacing would truncate the top of the mass: only
    u < n_active/P would survive the mask).  Full-width rows are bitwise
    the vmapped dense :func:`stratified` (same draws, same division).
    """
    p = weights.shape[-1]

    def row(key, w, n):
        cdf = _masked_cdf_row(w, policy)
        us = jax.random.uniform(key, (p,), dtype=cdf.dtype)
        u = (jnp.arange(p, dtype=cdf.dtype) + us) / jnp.maximum(n, 1).astype(
            cdf.dtype
        )
        return _search(cdf, u)

    return jax.vmap(row)(keys, weights, n_active)


def multinomial_masked_banked(
    keys: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    n_active: jax.Array,
) -> jax.Array:
    """Ragged multinomial resampling: P iid draws, *unsorted*.

    The dense :func:`multinomial` sorts its uniforms (a search-locality
    optimization); under a mask the first n_active of P *sorted* draws are
    order statistics — biased toward the low-CDF prefix — so the masked
    form inverts unsorted uniforms: any prefix of iid draws is iid.  A
    full-width ragged row therefore matches the dense kernel in
    *distribution* (same draw multiset, different lane order), not bitwise;
    the ragged bank's bit-exact dense equivalence holds for the grid-based
    resamplers (systematic, stratified).
    """
    p = weights.shape[-1]

    def row(key, w, n):
        del n  # every draw covers the full active CDF
        cdf = _masked_cdf_row(w, policy)
        u = jax.random.uniform(key, (p,), dtype=cdf.dtype)
        return _search(cdf, u)

    return jax.vmap(row)(keys, weights, n_active)


def metropolis_masked_banked(
    keys: jax.Array,
    weights: jax.Array,
    policy: PrecisionPolicy,
    n_active: jax.Array,
) -> jax.Array:
    """Ragged Metropolis resampling: the dense banked chains are already
    mask-correct.  Proposals land anywhere in [0, P), but a zero-weight
    (inactive) proposal can never be accepted from an active ancestor
    (``u * w_k < 0`` is false), so active lanes only ever adopt active
    ancestors; inactive output lanes are junk the engine masks.
    """
    del n_active
    return jax.vmap(lambda k, w: metropolis(k, w, policy))(keys, weights)


# Masked (ragged-bank) reference forms by resampler name — the pure-jnp
# fallbacks :class:`repro.core.engine.FilterBank` uses when the backend has
# no fused masked kernel.  A resampler absent here (a custom registration)
# cannot run ragged unless its backend supplies a masked form: the dense
# grids silently truncate the active mass, so the engine raises instead.
MASKED_RESAMPLERS: dict[str, Resampler] = {}

# Resamplers that *deliberately* ship no masked form (none today).  An entry
# here tells the registry-completeness analysis rule the gap is a decision,
# not an oversight; the engine still raises if a ragged bank requests one.
MASKED_OPT_OUTS: set[str] = set()


RESAMPLERS: dict[str, Resampler] = {}


# ---------------------------------------------------------------------------
# Fused weight-epilogue references: the whole per-frame weight pipeline
# (normalize -> Kish-ESS sums -> resample) as ONE pure-jnp function per
# registered resampler.  These are (a) the oracles the fused Pallas kernel
# is tested against at the engine level and (b) the jnp backend's
# registered ``Backend.fused_epilogue*`` forms — written to be *bitwise*
# the composed unfused jnp chain (same normalize ops as the engine's
# ``_jnp_normalize``, same sum order as ``stability.effective_sample_size``,
# same resampler call), so dispatching through them changes no numbers.
#
# Return convention (the Backend fused-epilogue contract):
#     (weights, ancestors, log_z, max_log_w, sum_w, sum_w2)
# with ESS = sum_w^2 / sum_w2 derived by the engine.


def reference_normalize(log_w: jax.Array, policy: PrecisionPolicy):
    """The jnp backend's normalize: (weights, lse, max) over the trailing
    axis, LSE in accum dtype.  This is the ONE definition — the engine's
    jnp Backend aliases it and the fused references below compose it, so
    the fused == composed bitwise contract on the jnp backend is
    structural, not a copy-paste discipline."""
    m = jnp.max(log_w)
    lse = stability.logsumexp(log_w.astype(policy.accum_dtype), axis=-1)
    w = jnp.exp(log_w.astype(policy.accum_dtype) - lse).astype(log_w.dtype)
    return w, lse, m


def make_fused_epilogue_reference(resampler: Resampler):
    """Single-filter fused reference: (key, log_w (P,), policy) -> 6-tuple."""

    def fused(key, log_w, policy):
        w, lse, m = reference_normalize(log_w, policy)
        w_acc = w.astype(policy.accum_dtype)
        sum_w = jnp.sum(w_acc, axis=-1)
        sum_w2 = jnp.sum(jnp.square(w_acc), axis=-1)
        anc = resampler(key, w, policy)
        return w, anc, lse, m, sum_w, sum_w2

    return fused


def make_fused_epilogue_banked_reference(resampler: Resampler):
    """Banked fused reference: (keys (B,), log_w (B, P), policy) -> 6-tuple
    with (B,) stats — the per-row vmap of the single reference, matching
    the engine's banked jnp fallbacks row for row."""

    def fused(keys, log_w, policy):
        w, lse, m = jax.vmap(
            lambda row: reference_normalize(row, policy)
        )(log_w)
        w_acc = w.astype(policy.accum_dtype)
        sum_w = jnp.sum(w_acc, axis=-1)
        sum_w2 = jnp.sum(jnp.square(w_acc), axis=-1)
        anc = jax.vmap(lambda k, row: resampler(k, row, policy))(keys, w)
        return w, anc, lse, m, sum_w, sum_w2

    return fused


def make_fused_epilogue_masked_reference(masked_resampler: Resampler):
    """Ragged fused reference: (keys, log_w, policy, n_active) -> 6-tuple.

    Normalization runs the dense banked ops on the engine's pre-masked
    rows (inactive lanes already -inf, weight exactly 0 — zero
    contribution to every sum), matching the engine's masked-normalize
    fallback; resampling uses the count-aware masked reference.
    """

    def fused(keys, log_w, policy, n_active):
        w, lse, m = jax.vmap(
            lambda row: reference_normalize(row, policy)
        )(log_w)
        w_acc = w.astype(policy.accum_dtype)
        sum_w = jnp.sum(w_acc, axis=-1)
        sum_w2 = jnp.sum(jnp.square(w_acc), axis=-1)
        anc = masked_resampler(keys, w, policy, n_active)
        return w, anc, lse, m, sum_w, sum_w2

    return fused


# ---------------------------------------------------------------------------
# Fused full-step references: the fused epilogue with the intensity
# likelihood composed in front — the pure-jnp oracles for the streaming
# ``repro.kernels.step`` kernel and the jnp backend's registered
# ``Backend.fused_step*`` forms.  Each is literally "score the gathered
# patches, add the prior log-weight, run the fused epilogue reference", so
# fused-step == composed is structural on the jnp backend too.
#
# Return convention (the Backend fused-step contract): the fused-epilogue
# 6-tuple ``(weights, ancestors, log_z, max_log_w, sum_w, sum_w2)``.


def make_fused_step_reference(fused_epilogue):
    """Single-filter fused-step reference:
    (key, patches (P, J), model, prior scalar, policy) -> 6-tuple."""

    def fused_step(key, patches, model, prior, policy):
        from repro.core import likelihood

        cdt = policy.compute_dtype
        ll = likelihood.intensity_loglik(patches, model, policy).astype(cdt)
        log_w = prior.astype(cdt) + ll
        return fused_epilogue(key, log_w, policy)

    return fused_step


def make_fused_step_banked_reference(fused_epilogue_banked):
    """Banked fused-step reference: (keys (B,), patches (B, P, J), model,
    prior (B,), policy) -> 6-tuple with (B,) stats."""

    def fused_step(keys, patches, model, prior, policy):
        from repro.core import likelihood

        cdt = policy.compute_dtype
        ll = jax.vmap(
            lambda p: likelihood.intensity_loglik(p, model, policy)
        )(patches).astype(cdt)
        log_w = prior.astype(cdt)[:, None] + ll
        return fused_epilogue_banked(keys, log_w, policy)

    return fused_step


def make_fused_step_masked_reference(fused_epilogue_masked):
    """Ragged fused-step reference: ``prior`` is the (B,) per-slot
    ``log_uniform`` and lanes >= n_active[b] enter the epilogue at -inf
    (weight exactly 0) no matter what junk their patch lanes hold."""

    def fused_step(keys, patches, model, prior, policy, n_active):
        from repro.core import likelihood

        cdt = policy.compute_dtype
        ll = jax.vmap(
            lambda p: likelihood.intensity_loglik(p, model, policy)
        )(patches).astype(cdt)
        lane = jnp.arange(ll.shape[-1])
        log_w = jnp.where(
            lane[None, :] < n_active[:, None],
            prior.astype(cdt)[:, None] + ll,
            jnp.asarray(-jnp.inf, cdt),
        )
        return fused_epilogue_masked(keys, log_w, policy, n_active)

    return fused_step


# Keyed by resampler name; register_resampler keeps these in sync so every
# registered resampler has a fused reference (the masked form additionally
# needs a MASKED_RESAMPLERS entry).
FUSED_EPILOGUES: dict[str, Callable] = {}
FUSED_EPILOGUES_BANKED: dict[str, Callable] = {}
FUSED_EPILOGUES_MASKED: dict[str, Callable] = {}
FUSED_STEPS: dict[str, Callable] = {}
FUSED_STEPS_BANKED: dict[str, Callable] = {}
FUSED_STEPS_MASKED: dict[str, Callable] = {}


def register_resampler(name: str, fn: Resampler | None = None):
    """Register ``fn`` under ``name`` (usable as a decorator).

    The registry is the extension point :class:`repro.core.engine.FilterConfig`
    dispatches on — mirroring ``precision.register_policy`` and
    ``engine.register_backend``.  A fused-epilogue reference (the composed
    normalize→ESS→resample chain as one function) is derived automatically,
    so every registered resampler can serve the engine's fused dispatch.
    """
    if fn is None:
        return lambda f: register_resampler(name, f)
    RESAMPLERS[name] = fn
    FUSED_EPILOGUES[name] = make_fused_epilogue_reference(fn)
    FUSED_EPILOGUES_BANKED[name] = make_fused_epilogue_banked_reference(fn)
    FUSED_STEPS[name] = make_fused_step_reference(FUSED_EPILOGUES[name])
    FUSED_STEPS_BANKED[name] = make_fused_step_banked_reference(
        FUSED_EPILOGUES_BANKED[name]
    )
    return fn


register_resampler("systematic", systematic)
register_resampler("stratified", stratified)
register_resampler("multinomial", multinomial)
register_resampler("metropolis", metropolis)

MASKED_RESAMPLERS.update(
    systematic=systematic_masked_banked,
    stratified=stratified_masked_banked,
    multinomial=multinomial_masked_banked,
    metropolis=metropolis_masked_banked,
)
FUSED_EPILOGUES_MASKED.update(
    {
        name: make_fused_epilogue_masked_reference(fn)
        for name, fn in MASKED_RESAMPLERS.items()
    }
)
FUSED_STEPS_MASKED.update(
    {
        name: make_fused_step_masked_reference(fn)
        for name, fn in FUSED_EPILOGUES_MASKED.items()
    }
)


def get_resampler(name: str) -> Resampler:
    try:
        return RESAMPLERS[name]
    except KeyError:
        raise KeyError(
            f"unknown resampler {name!r}; have {sorted(RESAMPLERS)}"
        ) from None


# Pre-registry name, kept for callers of the old lookup API.
make_resampler = get_resampler


def gather_ancestors(particles, ancestors: jax.Array):
    """Select ancestor states (pytree of (P, ...) arrays) by index."""
    return jax.tree.map(lambda x: jnp.take(x, ancestors, axis=0), particles)
