"""The particle-filter engine: one object, every execution axis a config key.

The paper's central move is a filter *generic over precision*
(``particleFilter<double/float/half>``); this module generalizes the idea to
every axis the repo has grown since: numeric policy, kernel backend,
resampling scheme, ESS threshold, and particle distribution over a device
mesh are all fields of one :class:`FilterConfig`, and one
:class:`ParticleFilter` executes any combination:

    flt = ParticleFilter(spec, FilterConfig(policy="bf16", backend="pallas"))
    state = flt.init(key, num_particles)
    state, out = flt.step(state, observation, key)      # one frame
    final, outs = flt.run(key, observations, num_particles)   # lax.scan
    for state, out in flt.stream(key, obs_iter, n): ...       # serving loop

Backends and resamplers are *registries* (:func:`register_backend`,
:func:`repro.core.resampling.register_resampler`), mirroring
``precision.register_policy``: the pure-jnp reference forms, the fused
Pallas kernel chain, and any future accelerator path are looked up by name,
never imported by the call site.  The multi-device filter is not a separate
entry point either — ``FilterConfig(mesh=..., scheme="local")`` routes the
same ``init``/``step``/``run`` through the shard_map step of
``repro.core.distributed`` (exact / local-RNA resampling schemes).

The legacy ``pf_step`` / ``pf_scan`` / ``track`` shims are gone; the jnp
backend remains bit-identical to the pre-engine functions they wrapped.

The bank axis
-------------

One large particle cloud saturates the device for one filter, but a
production tracker runs *many independent filters at once* — one per
tracked object, one per serving request.  :class:`FilterBank` adds that
axis: ``B`` filters sharing one :class:`~repro.core.filter.SMCSpec` and one
:class:`FilterConfig`, with per-slot state (leading bank axis on particles,
log-weights, and step counters) and per-slot keys, executed as one jitted
program.  The kernel chain is batch-dispatched — the Pallas backend runs
bank-wide kernels with per-row fp32 carries instead of vmapping single-
filter kernels — and per-slot lifecycle is dynamic: ``init_slot`` /
``reset_slot`` (re)start one slot by traced index, so a serving loop can
admit and retire requests mid-flight without recompiling.

    bank = FilterBank(spec, FilterConfig(policy="bf16"), num_slots=8)
    state = bank.init(key, num_particles)              # (8, P, ...) slots
    state, outs = bank.step(state, frame, keys, shared_obs=True)
    state = bank.reset_slot(state, slot=3, key=k2)     # restart slot 3
    final, outs = bank.run(key, video, num_particles)  # scan over frames

``FilterBank(spec, cfg, num_slots=1)`` is bit-identical to
``ParticleFilter(spec, cfg)`` — the B=1 key path collapses to the single-
filter path.  Multi-object tracking builds on this in
``repro.core.tracking.make_multi_tracker_filter`` (N targets = N slots over
one shared frame stream); continuous-batching serving in
``repro.launch.serve --smc`` (requests admitted into free slots mid-flight,
the bank stepping every tick regardless of occupancy).

Ragged banks
------------

A serving bank should also trade *particle count* per request: easy
targets track well at P=256 while hard ones need P=4096, and a dense bank
makes every slot pay the max.  ``init(key, P, n_active=counts)`` makes the
bank ragged: ``P`` stays the static lane width, but slot ``b`` filters
with its first ``n_active[b]`` lanes only — inactive lanes carry -inf
log-weight (weight exactly 0) through normalization, ESS, estimates and
the evidence, and resampling draws its systematic grid over the active
count, never selecting a padding lane.  ``init_slot(state, slot, key,
n_active=n)`` re-admits one slot at a new *traced* count — no recompile
per size, the contract the continuous-batching scheduler relies on.  A
ragged bank with every slot full is bit-identical to the dense bank (which
keeps its mask-free fast path), and a masked row's active prefix is
bitwise the unmasked width-n kernels (see ``repro.kernels``).

The bank composes with the mesh: ``FilterBank(spec, FilterConfig(mesh=...),
num_slots=B)`` shards slots over the "data" axis and each slot's particles
over "model", with per-slot collectives confined to the particle axes (see
``repro.core.distributed.make_dist_bank_step``) — the multi-device serving
configuration.

Ragged budgets are also *elastic*: ``resize_slot(state, slot, key, n)``
switches a live slot's budget mid-flight with a count-aware systematic
draw at the new count over its current posterior (slot and count traced —
no recompile), and ``repro.core.elastic.BudgetController`` closes the loop
by watching per-slot ESS and growing/shrinking budgets with hysteresis, a
cooldown, and an ESS-deficit arbiter under a global particle budget.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import resampling, stability
from repro.core.filter import FilterOutput, FilterState, SMCSpec
from repro.core.precision import PrecisionPolicy, get_policy

__all__ = [
    "Backend",
    "BACKENDS",
    "FilterBank",
    "FilterConfig",
    "ParticleFilter",
    "get_backend",
    "neg_log_count",
    "register_backend",
]


# ---------------------------------------------------------------------------
# Backend registry


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution backend for the per-frame kernel chain.

    normalize:  (log_w, policy) -> (weights, log_z, max_log_w) — the paper's
                max-finding + weighting + normalizing stages (Eq. 5).
    resamplers: per-resampler-name overrides ``(key, weights, policy) ->
                ancestors``; names without an override fall back to the
                registered pure-jnp resampler.

    Stats forms (one-read ESS — the engine derives ``ESS = sum_w^2 /
    sum_w2`` instead of re-reading the whole weight array):

    normalize_stats[_banked,_masked]: as the matching normalize form but
                returning ``(weights, log_z, max_log_w, sum_w, sum_w2)``
                with the Kish sums accumulated in the normalize pass
                itself; None falls back to the plain normalize plus jnp
                sums over its output (same values, one extra traversal).

    Fused-epilogue forms (the whole weight pipeline in one kernel pass —
    normalize + ESS sums + CDF + resample, the CDF never materialized in
    HBM; see ``repro.kernels.epilogue``), per-resampler-name maps
    ``... -> (weights, ancestors, log_z, max_log_w, sum_w, sum_w2)``:

    fused_epilogue:        (key, log_w (P,), policy) -> 6-tuple.
    fused_epilogue_banked: (keys (B,), log_w (B, P), policy) -> 6-tuple.
    fused_epilogue_masked: (keys, log_w, policy, n_active (B,)) -> 6-tuple
                — ragged twin, active prefix bitwise the dense kernel on a
                width-n row.
    Dispatch: ``FilterConfig.fused_epilogue`` (None=auto) selects these
    when the name is present; a backend without an entry runs the composed
    stats chain (bitwise the same result, more HBM traffic).  The jnp
    backend registers the pure-jnp references from
    ``resampling.FUSED_EPILOGUES*`` for every resampler.

    fused_finalize_banked / fused_finalize_masked: the *meshed* shard-local
                tail ``(log_w, lse, u0[, n_loc]) -> (weights, ancestors)``:
                given the globally merged LSE (one pmax + psum), one pass
                computes the shard's weights and chains the RNA ``local``
                scheme's shard-local systematic inverse on the in-VMEM CDF
                (replacing the separate exp + ancestors_from_u0 launches).

    Fused full-step forms (likelihood → weights → resample in ONE streaming
    pass — the epilogue with the intensity likelihood fused in front, the
    (B, P) log-weight array never materialized in HBM; see
    ``repro.kernels.step``), per-resampler-name maps with the same 6-tuple
    return as the fused epilogue:

    fused_step:        (key, patches (P, J), model, prior scalar, policy)
                -> 6-tuple.  ``prior`` is the carried uniform log-weight
                (always-resample path only, where the carry is constant).
    fused_step_banked: (keys (B,), patches (B, P, J), model, prior (B,),
                policy) -> 6-tuple with (B,) stats.
    fused_step_masked: (keys, patches, model, prior (B,), policy,
                n_active (B,)) -> 6-tuple — ragged twin; ``prior`` is the
                per-slot stored ``log_uniform`` and lanes past the count
                come out -inf/0 whatever junk their patch lanes hold.
    Dispatch: ``FilterConfig.fused_step`` (None=auto) selects these when
    the spec opts in via ``SMCSpec.step_fusion``; numerics are bitwise the
    composed ``loglik`` + fused-epilogue chain.  The jnp backend registers
    the pure-jnp references from ``resampling.FUSED_STEPS*``.

    fused_step_finalize_banked / fused_step_finalize_masked: the *meshed*
                shard-local head ``(log_w, patches, model, policy[, n_loc])
                -> (log_w', m (B,), lse (B,))``: likelihood + prior add +
                shard-local online-LSE stats in one pass; the engine merges
                the stats with one pmax + psum and chains the existing
                ``fused_finalize`` tail (RNA ``local`` scheme only).

    Banked forms (used by :class:`FilterBank`, leading bank axis B):

    normalize_banked:  (log_w (B, P), policy) -> (weights (B, P), log_z (B,),
                       max_log_w (B,)) in one launch; None falls back to
                       vmapping ``normalize``.
    resamplers_banked: per-resampler overrides ``(keys (B,), weights (B, P),
                       policy) -> ancestors (B, P)``; names without one fall
                       back to vmapping the registered pure-jnp resampler
                       (NOT the single-filter backend override — a bank
                       must never vmap a Pallas kernel).

    Masked forms (used by *ragged* :class:`FilterBank`\\ s — per-slot active
    lane counts ``n_active (B,)``; lanes past the count are padding):

    normalize_masked:  (log_w (B, P), n_active, policy) -> (weights, log_z,
                       max_log_w) with lanes >= n_active[b] pinned to -inf
                       inside the kernel carry (weight exactly 0); None
                       falls back to ``normalize_banked`` on the engine's
                       pre-masked log-weights.
    resamplers_masked: per-resampler overrides ``(keys (B,), weights (B, P),
                       policy, n_active) -> ancestors (B, P)`` drawing the
                       grid over the active count; names without one fall
                       back to the pure-jnp masked references in
                       ``resampling.MASKED_RESAMPLERS`` (count-aware grids
                       for the CDF family, mask-correct chains for
                       metropolis).  A resampler with neither masked form
                       cannot run ragged (the engine raises at init).

    Shard-local forms (used by the meshed :class:`FilterBank`, running
    *inside* shard_map on each device's (B_loc, P_loc) slice):

    local_stats_banked: (log_w (B, P_loc)) -> (max (B,), lse (B,)) fp32 —
                       the per-shard online-LSE state that
                       ``repro.core.distributed.dist_normalize_banked``
                       merges with one pmax + psum per row; None falls
                       back to the pure-jnp reduction.
    local_stats_masked: the ragged twin — ``(log_w, n_loc (B,)) -> (max,
                       lse)`` with per-row *shard-local* active counts
                       pinned to -inf inside the kernel carry; None falls
                       back to ``local_stats_banked`` on the pre-masked
                       rows.
    ancestors_from_u0_banked: per-resampler overrides ``(u0 (B,), weights
                       (B, P_loc)) -> ancestors (B, P_loc)`` for the RNA
                       ``local`` scheme's shard-local systematic inverse
                       (u0 already folds in the device index).
    ancestors_from_u0_masked: the ragged twin: ``(u0, weights, n_loc) ->
                       ancestors`` with per-row *shard-local* active counts.

    Application hooks:

    intensity_loglik:  (patches (P, J), model, policy) -> (P,) — the paper's
                       Rodinia intensity likelihood as a fused kernel;
                       ``repro.core.tracking`` dispatches on it so
                       ``backend="pallas"`` tracking runs the kernel without
                       the spec hard-coding backend names.  None falls back
                       to ``repro.core.likelihood.intensity_loglik``.
    """

    name: str
    normalize: Callable[[jax.Array, PrecisionPolicy], tuple]
    resamplers: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    normalize_banked: Callable[[jax.Array, PrecisionPolicy], tuple] | None = (
        None
    )
    resamplers_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    normalize_masked: Callable | None = None
    resamplers_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    normalize_stats: Callable | None = None
    normalize_stats_banked: Callable | None = None
    normalize_stats_masked: Callable | None = None
    fused_epilogue: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_epilogue_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_epilogue_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_finalize_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_finalize_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_step: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_step_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_step_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_step_finalize_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    fused_step_finalize_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    local_stats_banked: Callable[[jax.Array], tuple] | None = None
    local_stats_masked: Callable | None = None
    ancestors_from_u0_banked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    ancestors_from_u0_masked: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )
    intensity_loglik: Callable | None = None


BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown filter backend {name!r}; have {sorted(BACKENDS)}"
        ) from None


# The single definition lives in resampling (the fused jnp references
# compose it); aliasing it here keeps the jnp backend and the fused
# references bitwise-identical structurally rather than by copy-paste.
_jnp_normalize = resampling.reference_normalize


def _kish_sums(w: jax.Array, accum_dtype):
    """(sum w, sum w^2) in accum dtype — the two ESS reductions, in the
    same order ``stability.effective_sample_size`` runs them."""
    w_acc = w.astype(accum_dtype)
    return jnp.sum(w_acc, axis=-1), jnp.sum(jnp.square(w_acc), axis=-1)


def _jnp_normalize_stats(log_w: jax.Array, policy: PrecisionPolicy):
    w, lse, m = _jnp_normalize(log_w, policy)
    sum_w, sum_w2 = _kish_sums(w, policy.accum_dtype)
    return w, lse, m, sum_w, sum_w2


def _jnp_normalize_stats_banked(log_w: jax.Array, policy: PrecisionPolicy):
    w, lse, m = jax.vmap(lambda row: _jnp_normalize(row, policy))(log_w)
    sum_w, sum_w2 = _kish_sums(w, policy.accum_dtype)
    return w, lse, m, sum_w, sum_w2


def _pallas_normalize(log_w: jax.Array, policy: PrecisionPolicy):
    del policy  # the fused kernel carries its own fp32 accumulators
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse = lse_ops.normalize_weights(log_w)
    return w, lse, m


def _pallas_systematic(key: jax.Array, weights: jax.Array, policy):
    del policy
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_resample(key, weights)


def _pallas_normalize_banked(log_w: jax.Array, policy: PrecisionPolicy):
    del policy  # the batched kernel carries per-row fp32 accumulators
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse = lse_ops.normalize_weights_batched(log_w)
    return w, lse, m


def _pallas_systematic_banked(keys: jax.Array, weights: jax.Array, policy):
    del policy
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_resample_batched(keys, weights)


def _pallas_normalize_masked(
    log_w: jax.Array, n_active: jax.Array, policy: PrecisionPolicy
):
    del policy  # the masked kernel carries per-row fp32 accumulators
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse = lse_ops.normalize_weights_masked(log_w, n_active)
    return w, lse, m


def _pallas_systematic_masked(
    keys: jax.Array, weights: jax.Array, policy, n_active: jax.Array
):
    del policy
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_resample_masked(keys, weights, n_active)


def _pallas_local_stats_banked(log_w: jax.Array):
    from repro.kernels.logsumexp import ops as lse_ops

    return lse_ops.online_logsumexp_batched(log_w)


def _pallas_local_stats_masked(log_w: jax.Array, n_loc: jax.Array):
    from repro.kernels.logsumexp import ops as lse_ops

    return lse_ops.online_logsumexp_masked(log_w, n_loc)


def _pallas_ancestors_from_u0_banked(u0: jax.Array, weights: jax.Array):
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_ancestors_batched(u0, weights)


def _pallas_ancestors_from_u0_masked(
    u0: jax.Array, weights: jax.Array, n_active: jax.Array
):
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_ancestors_masked(u0, weights, n_active)


def _pallas_intensity_loglik(patches: jax.Array, model, policy):
    from repro.kernels.likelihood import ops as lik_ops

    return lik_ops.intensity_loglik(patches, model, policy)


def _pallas_normalize_stats(log_w: jax.Array, policy: PrecisionPolicy):
    del policy  # fp32 kernel carries; sums accumulated in the normalize phase
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats(log_w)
    return w, lse, m, sw, sw2


def _pallas_normalize_stats_banked(log_w: jax.Array, policy: PrecisionPolicy):
    del policy
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_batched(log_w)
    return w, lse, m, sw, sw2


def _pallas_normalize_stats_masked(
    log_w: jax.Array, n_active: jax.Array, policy: PrecisionPolicy
):
    del policy
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_masked(
        log_w, n_active
    )
    return w, lse, m, sw, sw2


def _pallas_fused_epilogue(key: jax.Array, log_w: jax.Array, policy):
    del policy
    from repro.kernels.epilogue import ops as epi_ops

    w, anc, lse, m, sw, sw2 = epi_ops.fused_epilogue(key, log_w)
    return w, anc, lse, m, sw, sw2


def _pallas_fused_epilogue_banked(keys: jax.Array, log_w: jax.Array, policy):
    del policy
    from repro.kernels.epilogue import ops as epi_ops

    return epi_ops.fused_epilogue_batched(keys, log_w)


def _pallas_fused_epilogue_masked(
    keys: jax.Array, log_w: jax.Array, policy, n_active: jax.Array
):
    del policy
    from repro.kernels.epilogue import ops as epi_ops

    return epi_ops.fused_epilogue_masked(keys, log_w, n_active)


def _pallas_fused_finalize_banked(
    log_w: jax.Array, lse: jax.Array, u0: jax.Array
):
    from repro.kernels.epilogue import ops as epi_ops

    return epi_ops.fused_finalize_from_u0_batched(u0, log_w, lse)


def _pallas_fused_finalize_masked(
    log_w: jax.Array, lse: jax.Array, u0: jax.Array, n_loc: jax.Array
):
    from repro.kernels.epilogue import ops as epi_ops

    return epi_ops.fused_finalize_from_u0_masked(u0, log_w, lse, n_loc)


def _pallas_fused_step(
    key: jax.Array, patches: jax.Array, model, prior: jax.Array, policy
):
    from repro.kernels.step import ops as step_ops

    return step_ops.fused_step(key, patches, model, prior, policy)


def _pallas_fused_step_banked(
    keys: jax.Array, patches: jax.Array, model, prior: jax.Array, policy
):
    from repro.kernels.step import ops as step_ops

    return step_ops.fused_step_batched(keys, patches, model, prior, policy)


def _pallas_fused_step_masked(
    keys: jax.Array,
    patches: jax.Array,
    model,
    prior: jax.Array,
    policy,
    n_active: jax.Array,
):
    from repro.kernels.step import ops as step_ops

    return step_ops.fused_step_masked(
        keys, patches, model, prior, policy, n_active
    )


def _pallas_fused_step_finalize_banked(
    log_w: jax.Array, patches: jax.Array, model, policy
):
    from repro.kernels.step import ops as step_ops

    return step_ops.fused_step_stats_batched(patches, log_w, model, policy)


def _pallas_fused_step_finalize_masked(
    log_w: jax.Array, patches: jax.Array, model, policy, n_loc: jax.Array
):
    from repro.kernels.step import ops as step_ops

    return step_ops.fused_step_stats_masked(
        patches, log_w, model, policy, n_loc
    )


register_backend(
    Backend(
        "jnp",
        _jnp_normalize,
        normalize_stats=_jnp_normalize_stats,
        normalize_stats_banked=_jnp_normalize_stats_banked,
        # Pure-jnp fused references (bitwise the composed chain) for every
        # registered resampler — live mappings, so later register_resampler
        # calls are picked up.
        fused_epilogue=resampling.FUSED_EPILOGUES,
        fused_epilogue_banked=resampling.FUSED_EPILOGUES_BANKED,
        fused_epilogue_masked=resampling.FUSED_EPILOGUES_MASKED,
        fused_step=resampling.FUSED_STEPS,
        fused_step_banked=resampling.FUSED_STEPS_BANKED,
        fused_step_masked=resampling.FUSED_STEPS_MASKED,
    )
)
register_backend(
    Backend(
        "pallas",
        _pallas_normalize,
        resamplers={"systematic": _pallas_systematic},
        normalize_banked=_pallas_normalize_banked,
        resamplers_banked={"systematic": _pallas_systematic_banked},
        normalize_masked=_pallas_normalize_masked,
        resamplers_masked={"systematic": _pallas_systematic_masked},
        normalize_stats=_pallas_normalize_stats,
        normalize_stats_banked=_pallas_normalize_stats_banked,
        normalize_stats_masked=_pallas_normalize_stats_masked,
        fused_epilogue={"systematic": _pallas_fused_epilogue},
        fused_epilogue_banked={"systematic": _pallas_fused_epilogue_banked},
        fused_epilogue_masked={"systematic": _pallas_fused_epilogue_masked},
        fused_finalize_banked={"systematic": _pallas_fused_finalize_banked},
        fused_finalize_masked={"systematic": _pallas_fused_finalize_masked},
        fused_step={"systematic": _pallas_fused_step},
        fused_step_banked={"systematic": _pallas_fused_step_banked},
        fused_step_masked={"systematic": _pallas_fused_step_masked},
        fused_step_finalize_banked={
            "systematic": _pallas_fused_step_finalize_banked
        },
        fused_step_finalize_masked={
            "systematic": _pallas_fused_step_finalize_masked
        },
        local_stats_banked=_pallas_local_stats_banked,
        local_stats_masked=_pallas_local_stats_masked,
        ancestors_from_u0_banked={
            "systematic": _pallas_ancestors_from_u0_banked
        },
        ancestors_from_u0_masked={
            "systematic": _pallas_ancestors_from_u0_masked
        },
        intensity_loglik=_pallas_intensity_loglik,
    )
)


# ---------------------------------------------------------------------------
# Config


@dataclasses.dataclass(frozen=True)
class FilterConfig:
    """Everything about *how* a filter executes (the model lives in SMCSpec).

    policy / backend / resampler are registry names (policy may also be a
    :class:`PrecisionPolicy` instance).  Setting ``mesh`` shards particles
    over the named mesh ``axis`` and switches resampling to the distributed
    ``scheme`` ("exact" global systematic, or "local" RNA with periodic ring
    exchange — see ``repro.core.distributed``).  Under a :class:`FilterBank`
    the mesh composes with the bank: slots shard over ``bank_axis`` and each
    slot's particles over ``axis`` — with the defaults (both "data") the
    bank resolves particles onto the "model" axis, i.e.
    ``FilterConfig(mesh=...)`` means slots × particles = "data" × "model".
    """

    policy: str | PrecisionPolicy = "fp32"
    backend: str = "jnp"
    resampler: str = "systematic"
    ess_threshold: float = 1.0  # resample when ESS < threshold * P
    # Fused weight epilogue (normalize + ESS + CDF + resample in one pass):
    # None = auto (use it whenever the backend registers a fused form for
    # the resampler — numerics are bitwise the composed chain, so auto is
    # safe); False = always run the composed chain (the benchmark
    # baseline); True = require a fused form: the banked kernel at
    # construction, the masked kernel at ragged init, and — on a meshed
    # bank — the local scheme's shard-local finalize (the exact scheme has
    # no fused form, so meshed exact + True raises).  The single
    # ParticleFilter applies it on the always-resample path
    # (ess_threshold >= 1.0); banks apply it at any threshold (both
    # branches are computed under the per-slot select anyway); naive
    # (stable_weighting=False) policies never fuse.
    fused_epilogue: bool | None = None
    # Fused full step (likelihood → weights → resample in one streaming
    # pass — the epilogue with the intensity likelihood fused in front;
    # the log-weight array never touches HBM).  Needs the spec to opt in
    # via ``SMCSpec.step_fusion``.  None = auto (fuse whenever the spec
    # opts in, the backend registers a fused-step form for the resampler
    # — respecting ``StepFusion.backend`` — and the path qualifies:
    # stable weighting, always-resample (the fused step consumes the
    # constant uniform carry), unmeshed or meshed-local-with-finalize);
    # False = always run the composed likelihood + epilogue chain; True =
    # require the fused form and raise wherever it cannot apply.  Like
    # fused_epilogue, numerics are bitwise the composed chain, so auto is
    # safe; naive policies never fuse.
    fused_step: bool | None = None
    # Distribution spec (None -> single placement).
    mesh: Any = None
    axis: str | tuple[str, ...] = "data"
    scheme: str = "exact"
    exchange_every: int = 4
    exchange_frac: float = 0.25
    bank_axis: str = "data"  # FilterBank slot axis (ignored by ParticleFilter)

    def with_(self, **kw: Any) -> "FilterConfig":
        return dataclasses.replace(self, **kw)


def neg_log_count(n, dtype):
    """``-log(n)`` for a particle count, bit-stable across call sites.

    Concrete counts go through host double log then one rounding to
    ``dtype`` — exactly the bits of the Python-constant path the dense
    filter uses (``-jnp.log(float(P))``).  Traced counts (recompile-free
    ragged ``init_slot``) use the runtime fp32 log.  The two can differ by
    1 ulp for some counts (XLA's folded log != its runtime vectorized log),
    which is why the engine *stores* the value per slot (``FilterState.
    log_uniform``) instead of recomputing it: every reset in a slot's
    lifetime reuses identical bits.
    """
    import numpy as np

    if isinstance(n, jax.core.Tracer):
        return (-jnp.log(n.astype(jnp.float32))).astype(dtype)
    return jnp.asarray(-np.log(np.asarray(n, np.float64)), dtype)


# ---------------------------------------------------------------------------
# Engine


class ParticleFilter:
    """A configured filter over one SMC model.

    Construction resolves every registry name (raising immediately on
    unknown policies/backends/resamplers/schemes); the step methods are pure
    jax functions of their array arguments, safe under jit/scan.
    """

    def __init__(self, spec: SMCSpec, config: FilterConfig | None = None):
        self.spec = spec
        self.config = config = config or FilterConfig()
        self.policy = (
            get_policy(config.policy)
            if isinstance(config.policy, str)
            else config.policy
        )
        self.backend = get_backend(config.backend)
        base_resampler = resampling.get_resampler(config.resampler)
        override = self.backend.resamplers.get(config.resampler)
        self._resample = override or base_resampler

        # Fused weight-epilogue dispatch (one pass: normalize + ESS sums +
        # CDF + resample).  Resolved at construction; numerics are bitwise
        # the composed chain, so auto (None) enables it whenever the
        # backend registers a form for this resampler.
        self._fused = None
        if config.fused_epilogue is not False and self.policy.stable_weighting:
            self._fused = self.backend.fused_epilogue.get(config.resampler)
        if config.fused_epilogue is True:
            if config.mesh is not None:
                # The meshed single filter runs make_dist_pf_step, which
                # has no fused form — requiring fusion there would
                # silently deliver the composed chain.
                raise ValueError(
                    "fused_epilogue=True is not available on a meshed "
                    "ParticleFilter (the distributed single-filter step "
                    "has no fused form); use a meshed FilterBank with "
                    "scheme='local' for the shard-local fused finalize"
                )
            if self._fused is None:
                raise ValueError(
                    f"fused_epilogue=True but backend "
                    f"{self.backend.name!r} registers no fused epilogue "
                    f"for resampler {config.resampler!r} (or the policy's "
                    "naive weighting path is active)"
                )

        # Fused full-step dispatch (likelihood → weights → resample in one
        # pass, see FilterConfig.fused_step).  The fused step consumes the
        # *constant uniform* prior carry, so it only applies on the static
        # always-resample path; every other gate mirrors fused_epilogue.
        self._fused_step = None
        fstep = spec.step_fusion
        if (
            config.fused_step is not False
            and fstep is not None
            and self.policy.stable_weighting
            and (fstep.backend is None or fstep.backend == config.backend)
            and config.ess_threshold >= 1.0
            and config.mesh is None
        ):
            self._fused_step = self.backend.fused_step.get(config.resampler)
        if config.fused_step is True:
            if fstep is None:
                raise ValueError(
                    "fused_step=True needs the spec to opt in via "
                    "SMCSpec.step_fusion (the engine cannot split an "
                    "opaque loglik into gather + intensity model)"
                )
            if config.mesh is not None:
                raise ValueError(
                    "fused_step=True is not available on a meshed "
                    "ParticleFilter (the distributed single-filter step "
                    "has no fused form); use a meshed FilterBank with "
                    "scheme='local' for the shard-local fused-step head"
                )
            if config.ess_threshold < 1.0:
                raise ValueError(
                    "fused_step=True requires ess_threshold >= 1.0: the "
                    "fused step folds the constant uniform prior into its "
                    "likelihood pass, which only matches the composed "
                    "chain when every frame resamples"
                )
            if self._fused_step is None:
                raise ValueError(
                    f"fused_step=True but backend {self.backend.name!r} "
                    f"registers no fused step for resampler "
                    f"{config.resampler!r}, the policy's naive weighting "
                    "path is active, or StepFusion.backend names a "
                    "different backend"
                )

        self._dist_step = None
        if config.mesh is not None:
            from repro.core import distributed

            if spec.particle_axes is not None:
                raise ValueError(
                    "the meshed ParticleFilter assumes a leading particle "
                    "axis on every leaf; specs with particle_axes set "
                    "(non-leading cache axes) are only supported by the "
                    "meshed FilterBank — use FilterBank(spec, config, "
                    "num_slots=1)"
                )
            dist_cfg = distributed.DistributedConfig(
                mesh=config.mesh,
                axis=config.axis,
                scheme=config.scheme,
                exchange_every=config.exchange_every,
                exchange_frac=config.exchange_frac,
            )
            self._dist_cfg = dist_cfg
            self._dist_step = distributed.make_dist_pf_step(
                spec, self.policy, dist_cfg
            )

    # -- lifecycle ----------------------------------------------------------

    def init(self, key: jax.Array, num_particles: int) -> FilterState:
        """Draw the initial particle cloud with uniform weights."""
        particles = self.spec.init(key, num_particles)
        particles = jax.tree.map(
            lambda x: x.astype(self.policy.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.inexact)
            else x,
            particles,
        )
        log_w = jnp.full(
            (num_particles,),
            -jnp.log(float(num_particles)),
            self.policy.compute_dtype,
        )
        state = FilterState(particles, log_w, jnp.asarray(0, jnp.int32))
        if self._dist_step is not None:
            state = self._shard_state(state)
        return state

    def step(
        self, state: FilterState, observation: Any, key: jax.Array
    ) -> tuple[FilterState, FilterOutput]:
        """One frame: propagate → weight → normalize → estimate → resample."""
        if self._dist_step is not None:
            return self._step_distributed(state, observation, key)
        return self._step_local(state, observation, key)

    def run(
        self, key: jax.Array, observations: Any, num_particles: int
    ) -> tuple[FilterState, FilterOutput]:
        """Filter a whole sequence under ``lax.scan``.

        observations: pytree with a leading time axis (e.g. video (T, H, W)).
        Returns (final state, stacked per-step outputs).
        """
        k_init, k_run = jax.random.split(key)
        state0 = self.init(k_init, num_particles)
        num_steps = jax.tree.leaves(observations)[0].shape[0]
        step_keys = jax.random.split(k_run, num_steps)

        def body(state, xs):
            obs, k = xs
            return self.step(state, obs, k)

        return jax.lax.scan(body, state0, (observations, step_keys))

    def stream(
        self,
        key: jax.Array,
        observations: Any,
        num_particles: int,
        *,
        jit: bool = True,
    ):
        """Streaming filter for serving: yields (state, output) per frame.

        ``observations`` is any iterable — frames arriving from a queue, an
        endless generator, decode-step indices.  Per-step keys derive by
        ``fold_in`` of the step index so the stream never needs to know its
        length (unlike :meth:`run`, whose key path matches the legacy scan).
        """
        k_init, k_run = jax.random.split(key)
        state = self.init(k_init, num_particles)
        step = self.jit_step if jit else self.step
        for i, obs in enumerate(observations):
            state, out = step(state, obs, jax.random.fold_in(k_run, i))
            yield state, out

    @functools.cached_property
    def jit_step(self):
        """The step function jit-compiled once per engine instance."""
        return jax.jit(self.step)

    @functools.cached_property
    def jit_step_donated(self):
        """As :attr:`jit_step` with the input state's buffers donated: the
        step reuses the particle/weight memory instead of allocating a
        fresh copy.  For carried-state loops only — the passed-in state is
        *consumed* (its arrays are invalidated the moment the call is
        dispatched)."""
        return jax.jit(self.step, donate_argnums=(0,))

    # -- internals ----------------------------------------------------------

    def _normalize(self, log_w: jax.Array):
        if not self.policy.stable_weighting:
            # Paper's naive path: direct exponentiation, overflow and all.
            w, log_z = stability.normalize_log_weights(log_w, stable=False)
            return w, log_z, jnp.max(log_w)
        return self.backend.normalize(log_w, self.policy)

    def _normalize_stats(self, log_w: jax.Array):
        """Normalize + Kish sums: (w, log_z, max, sum_w, sum_w2).

        The stats backend accumulates the ESS sums in the normalize pass
        itself (no second traversal of the weights); backends without one
        fall back to the plain normalize plus jnp sums — same values.
        """
        if not self.policy.stable_weighting:
            w, log_z, m = self._normalize(log_w)
            sum_w, sum_w2 = _kish_sums(w, self.policy.accum_dtype)
            return w, log_z, m, sum_w, sum_w2
        impl = self.backend.normalize_stats
        if impl is not None:
            return impl(log_w, self.policy)
        w, log_z, m = self.backend.normalize(log_w, self.policy)
        sum_w, sum_w2 = _kish_sums(w, self.policy.accum_dtype)
        return w, log_z, m, sum_w, sum_w2

    def _step_local(self, state, observation, key):
        spec, policy = self.spec, self.policy
        cdt = policy.compute_dtype
        adt = policy.accum_dtype
        k_prop, k_res = jax.random.split(key)
        num_particles = state.log_weights.shape[0]

        # 1. propagation (paper kernel 1)
        particles = spec.transition(k_prop, state.particles, state.step)

        # 2-6. likelihood (kernel 2) + the weight epilogue.  Full-step
        # fusion: one streaming pass scores the gathered patches, adds the
        # constant uniform prior (the always-resample carry), and runs the
        # whole epilogue — the log-weight array never touches HBM.  Fused
        # epilogue (always-resample): likelihood first, then one kernel
        # pass for weights + ancestors + stats.  Composed path: normalize
        # (with in-pass ESS sums) now, resample under the cond below.
        ancestors = None
        use_fused = (
            self._fused is not None and self.config.ess_threshold >= 1.0
        )
        if self._fused_step is not None:
            fstep = spec.step_fusion
            patches = fstep.gather(particles, observation, state.step)
            prior = neg_log_count(num_particles, cdt)
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = (
                self._fused_step(k_res, patches, fstep.model, prior, policy)
            )
        elif use_fused:
            log_lik = spec.loglik(
                particles, observation, state.step
            ).astype(cdt)
            log_w = state.log_weights + log_lik
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = self._fused(
                k_res, log_w, policy
            )
        else:
            log_lik = spec.loglik(
                particles, observation, state.step
            ).astype(cdt)
            log_w = state.log_weights + log_lik
            weights, log_z, max_lw, sum_w, sum_w2 = self._normalize_stats(
                log_w
            )
        prev_lse = stability.logsumexp(
            state.log_weights.astype(adt), axis=-1
        )
        log_z_inc = log_z - prev_lse
        w_accum = weights.astype(adt)
        ess = jnp.square(sum_w.astype(adt)) / sum_w2.astype(adt)

        if spec.summary is not None:
            estimate = spec.summary(particles, w_accum)
        else:
            estimate = _weighted_mean(particles, weights, adt)

        # 6. resampling (kernel 6)
        gather = self.spec.gather or resampling.gather_ancestors

        def _resampled():
            anc = (
                ancestors
                if ancestors is not None
                else self._resample(k_res, weights, policy)
            )
            new_particles = gather(particles, anc)
            # Sized off the carried weights: log_w never materializes on
            # the fused-step path.
            uniform = jnp.full_like(
                state.log_weights, -jnp.log(float(num_particles))
            )
            return new_particles, uniform

        def _kept():
            return particles, jnp.log(
                weights.astype(policy.accum_dtype)
            ).astype(state.log_weights.dtype)

        # threshold >= 1.0 means "always resample" (ESS can never exceed P),
        # gated statically; sub-1.0 thresholds compare *exactly* — a fudge
        # term here (the old ``+ 0.5``) makes them fire early.
        if self.config.ess_threshold >= 1.0:
            do_resample = jnp.asarray(True)
            new_particles, new_log_w = _resampled()
        else:
            do_resample = ess < self.config.ess_threshold * num_particles
            new_particles, new_log_w = jax.lax.cond(
                do_resample, _resampled, _kept
            )

        new_state = FilterState(
            particles=new_particles,
            log_weights=new_log_w,
            step=state.step + 1,
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=log_z_inc,
            resampled=do_resample,
            max_loglik=max_lw,
        )
        return new_state, out

    def _step_distributed(self, state, observation, key):
        # Both distributed schemes resample every frame; the evidence
        # increment closes over the (globally sharded) pre-step weights.
        prev_lse = stability.logsumexp(
            state.log_weights.astype(self.policy.accum_dtype), axis=-1
        )
        particles, log_w, step, estimate, ess, lse, max_lw = self._dist_step(
            state.particles, state.log_weights, state.step, observation, key
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=lse - prev_lse,
            resampled=jnp.asarray(True),
            max_loglik=max_lw,
        )
        return FilterState(particles, log_w, step), out

    def _shard_state(self, state: FilterState) -> FilterState:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        sh = NamedSharding(self.config.mesh, P(self._dist_cfg.axes))

        def place(x):
            if isinstance(x, jax.core.Tracer):
                return jax.lax.with_sharding_constraint(x, sh)
            return jax.device_put(x, sh)

        return FilterState(
            particles=jax.tree.map(place, state.particles),
            log_weights=place(state.log_weights),
            step=state.step,
        )


# ---------------------------------------------------------------------------
# FilterBank: B independent filters as one jitted program


class FilterBank:
    """``num_slots`` independent filters over one shared SMCSpec and config.

    State carries a leading bank axis: particles ``(B, P, ...)``, log-weights
    ``(B, P)``, per-slot step counters ``(B,)``.  Every per-frame stage is
    vectorized over the bank — spec callables via ``vmap``, the normalize /
    resample kernel chain via the backend's banked entry points (the Pallas
    backend runs one kernel launch for the whole bank with per-row fp32
    carries).  Slots never interact: no weight, resampling, or key traffic
    crosses rows.

    Lifecycle is per-slot and recompile-free: :meth:`init_slot` /
    :meth:`reset_slot` take a *traced* slot index, so a continuous-batching
    serving loop admits a request into a free slot and retires it on
    completion while the bank steps every tick regardless of occupancy.

    ``FilterBank(spec, cfg, num_slots=1)`` is bit-identical to
    ``ParticleFilter(spec, cfg)``: with one slot the key derivation
    collapses to the single-filter path and every banked stage reduces over
    the same elements in the same order.

    Quickstart (multi-object tracking — N targets over one frame stream)::

        from repro.core import TrackerConfig, get_policy
        from repro.core.tracking import make_multi_tracker_filter

        starts = jnp.asarray([[64.0, 64.0], [192.0, 64.0]])   # per-target
        bank = make_multi_tracker_filter(
            TrackerConfig(num_particles=4096), get_policy("bf16"), starts
        )
        final, outs = bank.run(jax.random.key(0), video, 4096)
        trajectories = outs.estimate["pos"]                   # (T, N, 2)

    Mesh distribution composes with the bank axis:
    ``FilterConfig(mesh=...)`` shards slots over the ``bank_axis`` mesh
    axis ("data") and each slot's particles over ``axis`` (resolved to
    "model" when left at its single-filter default), and routes the step
    through the shard_map'd banked step of ``repro.core.distributed`` —
    per-slot online-LSE normalization merged with one ``pmax`` + ``psum``
    per row, per-slot ``exact`` all-gather or ``local`` RNA ring-exchange
    resampling, no collective ever crossing the bank axis.  Like the meshed
    :class:`ParticleFilter`, the distributed schemes resample every frame
    (``ess_threshold`` is ignored), and a meshed ``B=1`` bank in ``exact``
    mode is bit-comparable to the meshed single filter.  ``num_slots`` must
    divide by the bank-axis size and ``num_particles`` by the particle-axes
    size; ``init_slot`` / ``reset_slot`` stay recompile-free and place the
    reset onto the correct shard, so the continuous-batching scheduler in
    ``repro.launch.serve`` admits mid-flight on a sharded bank::

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bank = FilterBank(spec, FilterConfig(mesh=mesh, scheme="local"),
                          num_slots=8)              # 4 slots per data shard
        state = bank.init(key, 4096)                # 1024 particles/device
    """

    def __init__(
        self,
        spec: SMCSpec,
        config: FilterConfig | None = None,
        num_slots: int = 1,
    ):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        config = config or FilterConfig()
        # Reuse the single-filter engine for registry resolution/validation
        # (mesh withheld: the bank owns its own distributed step).
        self.filter = ParticleFilter(spec, config.with_(mesh=None))
        self.spec = spec
        self.config = config
        self.policy = self.filter.policy
        self.backend = self.filter.backend
        self.num_slots = num_slots

        self._dist_cfg = None
        self._dist_steps: dict[tuple[bool, bool], Callable] = {}
        if config.mesh is not None:
            from repro.core import distributed

            part_axes = (
                (config.axis,)
                if isinstance(config.axis, str)
                else tuple(config.axis)
            )
            if part_axes == (config.bank_axis,):
                # Single-filter default: slots take the bank axis,
                # particles move to "model".
                part_axes = ("model",)
            mesh_names = tuple(config.mesh.axis_names)
            for a in (config.bank_axis, *part_axes):
                if a not in mesh_names:
                    raise ValueError(
                        f"mesh has no axis {a!r} (mesh axes: {mesh_names}); "
                        f"a meshed FilterBank shards slots over "
                        f"bank_axis={config.bank_axis!r} and particles "
                        f"over {part_axes}"
                    )
            self._dist_cfg = distributed.DistributedConfig(
                mesh=config.mesh,
                axis=part_axes,
                scheme=config.scheme,
                exchange_every=config.exchange_every,
                exchange_frac=config.exchange_frac,
                bank_axis=config.bank_axis,
            )

        banked_norm = self.backend.normalize_banked
        if banked_norm is None:
            base_norm = self.backend.normalize

            def banked_norm(log_w, policy):
                w, lse, m = jax.vmap(
                    lambda row: base_norm(row, policy)
                )(log_w)
                return w, lse, m

        self._normalize_banked_impl = banked_norm

        banked_res = self.backend.resamplers_banked.get(config.resampler)
        if banked_res is None:
            base_res = resampling.get_resampler(config.resampler)

            def banked_res(keys, weights, policy):
                return jax.vmap(
                    lambda k, row: base_res(k, row, policy)
                )(keys, weights)

        self._resample_banked = banked_res

        # Ragged (masked) forms.  Normalize: the engine pins inactive lanes
        # to -inf before the call, so the dense banked kernel is already
        # correct; a backend masked kernel (count input) is preferred as it
        # is junk-proof without the pre-mask.  Resample: backend masked
        # kernel first, then the pure-jnp masked reference
        # (``resampling.MASKED_RESAMPLERS`` — count-aware grids for the
        # CDF family, mask-correct chains for metropolis).  A custom
        # resampler with neither stays None and ragged init raises: its
        # dense grid would silently truncate the active mass.
        masked_norm = self.backend.normalize_masked
        if masked_norm is None:
            dense_norm = self._normalize_banked_impl

            def masked_norm(log_w, n_active, policy):
                del n_active  # log_w is pre-masked to -inf past the count
                return dense_norm(log_w, policy)

        self._normalize_masked_impl = masked_norm

        self._resample_masked = self.backend.resamplers_masked.get(
            config.resampler
        ) or resampling.MASKED_RESAMPLERS.get(config.resampler)

        # Budget-switch primitive (see resize_slot / repro.core.elastic):
        # the count-aware draw over one slot's current posterior.  Unmeshed
        # banks reuse the same masked resample form the ragged step
        # dispatches (backend kernel when registered); meshed banks use the
        # pure-jnp masked reference — the backend kernels are shard-local,
        # and a resize is a rare bank-global event on the row view, not a
        # per-step collective.
        if config.mesh is not None:
            self._resize_resampler = resampling.MASKED_RESAMPLERS.get(
                config.resampler
            )
        else:
            self._resize_resampler = self._resample_masked

        # Stats forms (normalize + in-pass Kish sums).  Fallbacks wrap the
        # plain normalize and sum its output — same values, one extra
        # weight traversal.
        stats_banked = self.backend.normalize_stats_banked
        if stats_banked is None:
            dense_norm_impl = self._normalize_banked_impl

            def stats_banked(log_w, policy):
                w, lse, m = dense_norm_impl(log_w, policy)
                sum_w, sum_w2 = _kish_sums(w, policy.accum_dtype)
                return w, lse, m, sum_w, sum_w2

        self._normalize_stats_banked_impl = stats_banked

        stats_masked = self.backend.normalize_stats_masked
        if stats_masked is None:
            dense_stats = self._normalize_stats_banked_impl

            def stats_masked(log_w, n_active, policy):
                del n_active  # log_w is pre-masked to -inf past the count
                return dense_stats(log_w, policy)

        self._normalize_stats_masked_impl = stats_masked

        # Fused weight-epilogue dispatch (see FilterConfig.fused_epilogue).
        self._fused_banked = None
        self._fused_masked = None
        if (
            config.fused_epilogue is not False
            and self.policy.stable_weighting
        ):
            self._fused_banked = self.backend.fused_epilogue_banked.get(
                config.resampler
            )
            self._fused_masked = self.backend.fused_epilogue_masked.get(
                config.resampler
            )
        if config.fused_epilogue is True:
            if self._dist_cfg is not None:
                # Meshed banks run the distributed step: the only fused
                # form there is the local scheme's shard-local finalize.
                if config.scheme != "local" or (
                    self.backend.fused_finalize_banked.get(config.resampler)
                    is None
                    or not self.policy.stable_weighting
                ):
                    raise ValueError(
                        "fused_epilogue=True on a meshed bank requires "
                        "scheme='local' and a registered "
                        "Backend.fused_finalize_banked for resampler "
                        f"{config.resampler!r} (backend "
                        f"{self.backend.name!r}); the exact scheme has no "
                        "fused form (its CDF is all-gathered)"
                    )
            elif self._fused_banked is None:
                raise ValueError(
                    f"fused_epilogue=True but backend {self.backend.name!r} "
                    f"registers no banked fused epilogue for resampler "
                    f"{config.resampler!r} (or the policy's naive weighting "
                    "path is active)"
                )

        # Fused full-step dispatch (see FilterConfig.fused_step).  Same
        # always-resample constraint as the single filter — the fused step
        # folds the uniform prior carry into its likelihood pass — already
        # validated by the inner ParticleFilter above for fused_step=True.
        self._fused_step_banked = None
        self._fused_step_masked = None
        fstep = spec.step_fusion
        if (
            config.fused_step is not False
            and fstep is not None
            and self.policy.stable_weighting
            and (fstep.backend is None or fstep.backend == config.backend)
            and config.ess_threshold >= 1.0
            and self._dist_cfg is None
        ):
            self._fused_step_banked = self.backend.fused_step_banked.get(
                config.resampler
            )
            self._fused_step_masked = self.backend.fused_step_masked.get(
                config.resampler
            )
        if config.fused_step is True:
            if self._dist_cfg is not None:
                # Meshed banks run the distributed step: the only fused
                # head there is the local scheme's shard-local stats pass
                # chained into the fused finalize tail.
                if config.scheme != "local" or (
                    self.backend.fused_step_finalize_banked.get(
                        config.resampler
                    )
                    is None
                    or self.backend.fused_finalize_banked.get(
                        config.resampler
                    )
                    is None
                    or not self.policy.stable_weighting
                ):
                    raise ValueError(
                        "fused_step=True on a meshed bank requires "
                        "scheme='local' and registered "
                        "Backend.fused_step_finalize_banked + "
                        "fused_finalize_banked for resampler "
                        f"{config.resampler!r} (backend "
                        f"{self.backend.name!r}); the exact scheme has no "
                        "fused form (its CDF is all-gathered)"
                    )
                if config.fused_epilogue is False:
                    raise ValueError(
                        "fused_step=True with fused_epilogue=False is "
                        "contradictory on a meshed bank: the fused-step "
                        "head feeds the fused finalize tail, which "
                        "fused_epilogue=False disables"
                    )
            elif self._fused_step_banked is None:
                raise ValueError(
                    f"fused_step=True but backend {self.backend.name!r} "
                    f"registers no banked fused step for resampler "
                    f"{config.resampler!r} (or the policy's naive "
                    "weighting path is active)"
                )

        # Per-slot active-count default, set by factories (e.g. per-target
        # budgets in ``make_multi_tracker_filter``); ``init`` uses it when
        # no explicit ``n_active`` is passed.
        self.default_n_active = None

    # Jitted entry points shared across sibling banks (see :meth:`sibling`).
    # Every one of these reads its geometry — slot count, lane width — from
    # the *state argument's* shapes, never from ``self.num_slots``, so one
    # trace cache serves the whole family: N size-class banks cost one
    # compile per distinct state geometry, not N.
    _SHARED_ENTRY_POINTS = (
        "jit_step",
        "jit_step_shared",
        "jit_init_slot",
        "jit_step_donated",
        "jit_step_shared_donated",
        "jit_init_slot_donated",
        "jit_resize_slot",
        "jit_resize_slot_donated",
        "jit_reseed_slot",
        "jit_reseed_slot_donated",
        "jit_export_slot",
        "jit_import_slot",
        "jit_import_slot_donated",
    )

    def sibling(self, num_slots: int | None = None) -> "FilterBank":
        """A new bank sharing this bank's spec, config, and compiled code.

        The multi-bank packer (``repro.launch.serve``) builds one bank per
        particle size class; constructed independently, each would jit its
        own step/init_slot/resize_slot and N banks would N× compile.  The
        sibling re-runs registry resolution and validation (a fresh
        ``FilterBank``), then adopts this bank's jitted entry points —
        legal because every entry point is geometry-polymorphic (see
        ``_SHARED_ENTRY_POINTS``), so banks of any slot count and lane
        width share one trace cache and same-geometry states hit the same
        executable.
        """
        twin = FilterBank(
            self.spec,
            self.config,
            num_slots=self.num_slots if num_slots is None else num_slots,
        )
        for name in self._SHARED_ENTRY_POINTS:
            # cached_property: instance __dict__ entries shadow the
            # descriptor, so the donor's callables (materialized here if
            # not yet) become the sibling's.
            twin.__dict__[name] = getattr(self, name)
        return twin

    # -- lifecycle ----------------------------------------------------------

    def _init_slot_particles(self, key, num_particles: int, slot):
        init = self.spec.slot_init
        particles = (
            init(key, num_particles, slot)
            if init is not None
            else self.spec.init(key, num_particles)
        )
        return jax.tree.map(
            lambda x: x.astype(self.policy.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.inexact)
            else x,
            particles,
        )

    def init(
        self,
        key: jax.Array,
        num_particles: int,
        n_active: Any = None,
    ) -> FilterState:
        """Draw every slot's initial cloud from per-slot keys.

        B == 1 uses ``key`` unsplit so a one-slot bank reproduces
        ``ParticleFilter.init(key, P)`` bit for bit.

        ``n_active``: optional (B,) per-slot active lane counts — the ragged
        bank.  ``num_particles`` stays the static lane width of every slot;
        slot ``b`` filters with its first ``n_active[b]`` lanes only (the
        rest carry -inf log-weight and weight exactly 0).  Defaults to the
        bank's ``default_n_active`` (set by factories), else dense.
        """
        nb = self.num_slots
        keys = key[None] if nb == 1 else jax.random.split(key, nb)
        return self.init_slots(keys, num_particles, n_active)

    def init_slots(
        self,
        keys: jax.Array,
        num_particles: int,
        n_active: Any = None,
    ) -> FilterState:
        """Banked init from explicit per-slot keys ((B,) key array)."""
        nb = self.num_slots
        if n_active is None:
            n_active = self.default_n_active
        if self._dist_cfg is not None:
            self._check_mesh_divisibility(num_particles)
        particles = jax.vmap(
            lambda k, s: self._init_slot_particles(k, num_particles, s)
        )(keys, jnp.arange(nb, dtype=jnp.int32))
        if n_active is None:
            log_w = jnp.full(
                (nb, num_particles),
                -jnp.log(float(num_particles)),
                self.policy.compute_dtype,
            )
            state = FilterState(particles, log_w, jnp.zeros((nb,), jnp.int32))
        else:
            n_active = self._check_n_active(n_active, num_particles)
            cdt = self.policy.compute_dtype
            log_uniform = neg_log_count(n_active, cdt)
            lane = jnp.arange(num_particles)
            log_w = jnp.where(
                lane[None, :] < n_active[:, None],
                jnp.broadcast_to(
                    log_uniform[:, None], (nb, num_particles)
                ),
                jnp.asarray(-jnp.inf, cdt),
            )
            state = FilterState(
                particles,
                log_w,
                jnp.zeros((nb,), jnp.int32),
                n_active=n_active,
                log_uniform=log_uniform,
            )
        if self._dist_cfg is not None:
            state = self._shard_state(state)
        return state

    def _check_n_active(self, n_active, num_particles: int):
        n_active = jnp.asarray(n_active, jnp.int32)
        if n_active.shape != (self.num_slots,):
            raise ValueError(
                f"n_active must be shaped ({self.num_slots},) — one count "
                f"per slot — got {n_active.shape}"
            )
        if (
            self._resample_masked is None
            and self._fused_masked is None
            and self._dist_cfg is None
        ):
            raise ValueError(
                f"resampler {self.config.resampler!r} has no masked "
                "(ragged) form — its dense grid would truncate the active "
                "mass; register one via Backend.resamplers_masked, "
                "Backend.fused_epilogue_masked, or "
                "resampling.MASKED_RESAMPLERS"
            )
        if self.config.fused_epilogue is True:
            # Raggedness is decided here (the state pytree is fixed at
            # init), so this is the earliest the masked-form requirement
            # can be enforced — unmeshed banks need the masked epilogue
            # kernel, meshed local-scheme banks the masked finalize.
            if self._dist_cfg is None and self._fused_masked is None:
                raise ValueError(
                    f"fused_epilogue=True but backend "
                    f"{self.backend.name!r} registers no masked fused "
                    f"epilogue for resampler {self.config.resampler!r} — "
                    "a ragged bank would silently fall back to the "
                    "composed chain"
                )
            if (
                self._dist_cfg is not None
                and self.backend.fused_finalize_masked.get(
                    self.config.resampler
                )
                is None
            ):
                raise ValueError(
                    f"fused_epilogue=True but backend "
                    f"{self.backend.name!r} registers no masked fused "
                    f"finalize for resampler {self.config.resampler!r} — "
                    "a ragged meshed bank would silently fall back to "
                    "the composed chain"
                )
        if self.config.fused_step is True:
            # Same earliest-enforcement point as fused_epilogue above:
            # ragged dispatch needs the count-aware fused-step forms.
            if self._dist_cfg is None and self._fused_step_masked is None:
                raise ValueError(
                    f"fused_step=True but backend "
                    f"{self.backend.name!r} registers no masked fused "
                    f"step for resampler {self.config.resampler!r} — a "
                    "ragged bank would silently fall back to the "
                    "composed chain"
                )
            if (
                self._dist_cfg is not None
                and self.backend.fused_step_finalize_masked.get(
                    self.config.resampler
                )
                is None
            ):
                raise ValueError(
                    f"fused_step=True but backend "
                    f"{self.backend.name!r} registers no masked "
                    f"fused-step head for resampler "
                    f"{self.config.resampler!r} — a ragged meshed bank "
                    "would silently fall back to the composed chain"
                )
        self._check_count_range(n_active, num_particles)
        return n_active

    @staticmethod
    def _check_count_range(n, num_particles: int) -> None:
        """Concrete counts must fit the lane width (traced counts can't be
        checked at trace time; an oversized traced count would mis-scale
        the systematic grid, so admission paths validate what they can)."""
        if isinstance(n, jax.core.Tracer):
            return
        import numpy as np

        counts = np.atleast_1d(np.asarray(n))
        if (counts < 0).any() or (counts > num_particles).any():
            raise ValueError(
                f"n_active must lie in [0, {num_particles}] (the bank's "
                f"lane width); got {np.asarray(n).tolist()}"
            )

    def init_slot(
        self,
        state: FilterState,
        slot,
        key: jax.Array,
        n_active: Any = None,
        particles: Any = None,
    ) -> FilterState:
        """(Re)start one slot in place; ``slot`` may be traced (no recompile).

        The slot gets a fresh particle cloud, uniform weights, and step 0;
        every other slot's state is untouched bit for bit.

        On a ragged bank (state carries per-slot counts) ``n_active`` sets
        the slot's new active lane count — and may itself be *traced*, so a
        continuous-batching scheduler admits requests of any particle
        budget without recompiling.  Omitted, the slot restarts at full
        width.  Passing a count on a dense bank raises: raggedness changes
        the state pytree, which must be decided at ``init``.

        ``particles`` optionally supplies the slot's initial cloud directly
        (a pytree of per-slot rows at this bank's lane width) instead of
        drawing ``spec.init`` — the admission path for state prepared
        outside the bank, e.g. a prompt cache filled by a batched prefill
        pass and broadcast over the slot's lanes.  ``key`` is unused then.
        """
        num_particles = state.log_weights.shape[-1]
        slot = jnp.asarray(slot, jnp.int32)
        if particles is None:
            fresh = self._init_slot_particles(key, num_particles, slot)
        else:
            fresh = jax.tree.map(
                lambda x: x.astype(self.policy.compute_dtype)
                if jnp.issubdtype(x.dtype, jnp.inexact)
                else x,
                particles,
            )
        particles = jax.tree.map(
            lambda s, f: s.at[slot].set(f), state.particles, fresh
        )
        if state.n_active is None:
            if n_active is not None:
                raise ValueError(
                    "init_slot(n_active=...) needs a ragged bank; this "
                    "state is dense — init the bank with n_active to "
                    "enable per-slot counts (the state pytree cannot "
                    "change shape under jit)"
                )
            log_w = state.log_weights.at[slot].set(
                jnp.full(
                    (num_particles,),
                    -jnp.log(float(num_particles)),
                    state.log_weights.dtype,
                )
            )
            state = FilterState(particles, log_w, state.step.at[slot].set(0))
        else:
            if n_active is None:
                n = jnp.asarray(num_particles, jnp.int32)
            else:
                n = jnp.asarray(n_active, jnp.int32)
                self._check_count_range(n, num_particles)
            log_u = neg_log_count(n, state.log_weights.dtype)
            lane = jnp.arange(num_particles)
            row = jnp.where(
                lane < n,
                log_u,
                jnp.asarray(-jnp.inf, state.log_weights.dtype),
            )
            state = FilterState(
                particles,
                state.log_weights.at[slot].set(row),
                state.step.at[slot].set(0),
                n_active=state.n_active.at[slot].set(n),
                log_uniform=state.log_uniform.at[slot].set(log_u),
            )
        if self._dist_cfg is not None:
            # Pin the traced-index update back onto the bank sharding so a
            # reset never pulls slot state off its shard (the scatter
            # lowers to a masked in-place update on the owning device).
            state = self._shard_state(state)
        return state

    # A reset is a re-init: same fresh-cloud semantics, serving-loop name.
    reset_slot = init_slot

    def resize_slot(
        self,
        state: FilterState,
        slot,
        key: jax.Array,
        n_active,
    ) -> FilterState:
        """Switch one live slot's particle budget mid-flight.

        The budget switch is a *count-aware systematic draw at the new
        count* over the slot's current posterior: the u-grid spans
        ``n_active`` points against the CDF of the slot's present
        (old-count) weights, so resample-down to ``k`` is the in-VMEM CDF
        draw truncated to ``k`` lanes and resample-up is a re-draw at
        ``k`` (ancestors duplicate), both through the masked resample
        form the ragged step already dispatches.  The slot's weights
        reset to uniform over the new count (``log_uniform`` stored, as
        everywhere on the ragged path) and its *step counter is kept* —
        the request stays mid-flight; only its budget changed.  Every
        other slot is untouched bit for bit.

        ``slot`` and ``n_active`` may both be traced: budget transitions
        never recompile — the contract the elastic controller
        (``repro.core.elastic``) relies on, same as ragged admission.
        Dense banks raise (the state pytree cannot grow count fields
        under jit; init the bank ragged to make budgets dynamic).
        """
        if state.n_active is None:
            raise ValueError(
                "resize_slot needs a ragged bank; this state is dense — "
                "init the bank with n_active to make per-slot budgets a "
                "runtime value (the state pytree cannot change shape "
                "under jit)"
            )
        if self._resize_resampler is None:
            raise ValueError(
                f"resampler {self.config.resampler!r} has no masked "
                "(count-aware) form, so a budget switch cannot draw the "
                "new count; register one via Backend.resamplers_masked "
                "or resampling.MASKED_RESAMPLERS"
            )
        num_particles = state.log_weights.shape[-1]
        slot = jnp.asarray(slot, jnp.int32)
        n = jnp.asarray(n_active, jnp.int32)
        self._check_count_range(n, num_particles)
        policy = self.policy

        # The slot's current normalized weights (active lanes only carry
        # mass; padding lanes are -inf -> weight exactly 0), then the
        # count-aware draw: grid over the NEW count, CDF over the current
        # posterior.  Lanes >= n probe u >= 1 and clip to the CDF tail —
        # junk the uniform reset below masks to -inf.
        log_w_row = state.log_weights[slot]
        w_row, _, _ = resampling.reference_normalize(log_w_row, policy)
        ancestors = self._resize_resampler(
            key[None], w_row[None], policy, n[None]
        )[0]

        gather = self.spec.gather or resampling.gather_ancestors
        row_particles = jax.tree.map(lambda x: x[slot], state.particles)
        new_row = gather(row_particles, ancestors)
        particles = jax.tree.map(
            lambda s, f: s.at[slot].set(f), state.particles, new_row
        )
        log_u = neg_log_count(n, state.log_weights.dtype)
        lane = jnp.arange(num_particles)
        row = jnp.where(
            lane < n,
            log_u,
            jnp.asarray(-jnp.inf, state.log_weights.dtype),
        )
        state = FilterState(
            particles,
            state.log_weights.at[slot].set(row),
            state.step,  # mid-flight: the request keeps its progress
            n_active=state.n_active.at[slot].set(n),
            log_uniform=state.log_uniform.at[slot].set(log_u),
        )
        if self._dist_cfg is not None:
            state = self._shard_state(state)
        return state

    def reseed_slot(
        self,
        state: FilterState,
        slot,
        key: jax.Array,
        n_active: Any = None,
    ) -> FilterState:
        """Re-seed one live slot from the prior, keeping its progress.

        The elastic failure-recovery escalation (``repro.core.elastic``):
        a slot whose ESS stays pinned at collapse even at its maximum
        budget has lost the target — more lanes of the same degenerate
        posterior cannot recover it.  The re-seed draws a fresh
        (diffuse-prior) cloud with uniform weights — exactly admission —
        but *keeps the step counter*: the request stays mid-flight at its
        current position instead of restarting from step 0.

        On ragged banks ``n_active`` (traced ok) sets the re-seeded
        count; omitted, the slot keeps its current budget (unlike
        ``init_slot``, which defaults to full width — a re-seed is a
        recovery action, not a budget change).
        """
        num_particles = state.log_weights.shape[-1]
        slot = jnp.asarray(slot, jnp.int32)
        fresh = self._init_slot_particles(key, num_particles, slot)
        particles = jax.tree.map(
            lambda s, f: s.at[slot].set(f), state.particles, fresh
        )
        if state.n_active is None:
            if n_active is not None:
                raise ValueError(
                    "reseed_slot(n_active=...) needs a ragged bank; this "
                    "state is dense (the state pytree cannot change shape "
                    "under jit)"
                )
            log_w = state.log_weights.at[slot].set(
                jnp.full(
                    (num_particles,),
                    -jnp.log(float(num_particles)),
                    state.log_weights.dtype,
                )
            )
            state = FilterState(particles, log_w, state.step)
        else:
            if n_active is None:
                n = state.n_active[slot]
            else:
                n = jnp.asarray(n_active, jnp.int32)
                self._check_count_range(n, num_particles)
            log_u = neg_log_count(n, state.log_weights.dtype)
            lane = jnp.arange(num_particles)
            row = jnp.where(
                lane < n,
                log_u,
                jnp.asarray(-jnp.inf, state.log_weights.dtype),
            )
            state = FilterState(
                particles,
                state.log_weights.at[slot].set(row),
                state.step,  # mid-flight: only the cloud was replaced
                n_active=state.n_active.at[slot].set(n),
                log_uniform=state.log_uniform.at[slot].set(log_u),
            )
        if self._dist_cfg is not None:
            state = self._shard_state(state)
        return state

    def export_slot(self, state: FilterState, slot):
        """One slot's rows for cross-bank migration.

        Returns ``(particles_row, log_w_row, step)`` — the slot's particle
        pytree (lane-width rows), unnormalized log-weight row, and step
        counter — the exact inputs :meth:`import_slot` admits into another
        (possibly different-width) bank.  ``slot`` may be traced; the read
        is non-destructive (do not donate the state into it).
        """
        slot = jnp.asarray(slot, jnp.int32)
        rows = jax.tree.map(lambda x: x[slot], state.particles)
        return rows, state.log_weights[slot], state.step[slot]

    def import_slot(
        self,
        state: FilterState,
        slot,
        src_particles: Any,
        src_log_w: jax.Array,
        key: jax.Array,
        n_active,
        step,
    ) -> FilterState:
        """Admit an exported slot into this (possibly different-width) bank.

        The cross-class migration primitive behind the packed scheduler:
        the source slot's posterior (particle rows + log-weight row at the
        *source* lane width, from :meth:`export_slot`) is re-drawn at
        ``n_active`` through the same count-aware masked resampler
        :meth:`resize_slot` dispatches — the u-grid spans the new count,
        the CDF spans the source posterior.  A narrower source is
        zero-padded to this bank's width (padding carries exactly 0 mass
        so the draw never selects it); a wider source is truncated, which
        is only mass-preserving when its active lanes fit this width — the
        caller resizes the slot down first (the scheduler does).  Weights
        reset to uniform over ``n_active`` and the *source step counter*
        is installed: the request stays mid-flight, only its bank moved.

        ``slot``, ``n_active``, and ``step`` may all be traced; one
        compile per (source width, destination width) pair.  Requires a
        ragged destination (cross-width admission makes counts runtime
        values by construction).
        """
        if state.n_active is None:
            raise ValueError(
                "import_slot needs a ragged destination bank; this state "
                "is dense — init the bank with n_active so per-slot "
                "counts are runtime values"
            )
        if self._resize_resampler is None:
            raise ValueError(
                f"resampler {self.config.resampler!r} has no masked "
                "(count-aware) form, so a cross-bank migration cannot "
                "draw the new count; register one via "
                "Backend.resamplers_masked or resampling.MASKED_RESAMPLERS"
            )
        num_particles = state.log_weights.shape[-1]
        src_width = src_log_w.shape[-1]
        slot = jnp.asarray(slot, jnp.int32)
        n = jnp.asarray(n_active, jnp.int32)
        self._check_count_range(n, num_particles)
        policy = self.policy

        # Source posterior, resized to this bank's lane width: zero weight
        # is exactly "never an ancestor" on every masked-resampler path.
        w_src, _, _ = resampling.reference_normalize(src_log_w, policy)
        if src_width < num_particles:
            w_dst = (
                jnp.zeros((num_particles,), w_src.dtype)
                .at[:src_width]
                .set(w_src)
            )
        elif src_width > num_particles:
            w_dst = w_src[:num_particles]
        else:
            w_dst = w_src
        ancestors = self._resize_resampler(
            key[None], w_dst[None], policy, n[None]
        )[0]
        gather = self.spec.gather or resampling.gather_ancestors
        new_row = gather(src_particles, ancestors)
        new_row = jax.tree.map(
            lambda x: x.astype(self.policy.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.inexact)
            else x,
            new_row,
        )
        particles = jax.tree.map(
            lambda s, f: s.at[slot].set(f), state.particles, new_row
        )
        log_u = neg_log_count(n, state.log_weights.dtype)
        lane = jnp.arange(num_particles)
        row = jnp.where(
            lane < n,
            log_u,
            jnp.asarray(-jnp.inf, state.log_weights.dtype),
        )
        state = FilterState(
            particles,
            state.log_weights.at[slot].set(row),
            state.step.at[slot].set(jnp.asarray(step, jnp.int32)),
            n_active=state.n_active.at[slot].set(n),
            log_uniform=state.log_uniform.at[slot].set(log_u),
        )
        if self._dist_cfg is not None:
            state = self._shard_state(state)
        return state

    def step(
        self,
        state: FilterState,
        observations: Any,
        keys: jax.Array,
        *,
        shared_obs: bool = False,
    ) -> tuple[FilterState, FilterOutput]:
        """One frame for every slot (idle slots step too — no ragged grid).

        observations: pytree with a leading bank axis (one observation per
        slot), or a single shared observation with ``shared_obs=True`` (the
        multi-object tracker: every target sees the same frame).
        keys: (B,) per-slot PRNG keys.

        Ragged states (per-slot ``n_active``) take the masked path; dense
        states take the fast path below, which is exactly the pre-ragged
        banked step (no mask arithmetic when every slot is full-width).
        """
        if self._dist_cfg is not None:
            return self._step_distributed(state, observations, keys, shared_obs)
        if state.n_active is not None:
            return self._step_masked(state, observations, keys, shared_obs)
        spec, policy = self.spec, self.policy
        cdt = policy.compute_dtype
        nb, num_particles = state.log_weights.shape
        split = jax.vmap(jax.random.split)(keys)
        k_prop, k_res = split[:, 0], split[:, 1]
        obs_ax = None if shared_obs else 0

        # 1. propagation (paper kernel 1), per-slot keys and step counters
        particles = jax.vmap(spec.transition)(
            k_prop, state.particles, state.step
        )

        # 2-6. likelihood (kernel 2) + the weight epilogue.  Full-step
        # fusion: one streaming pass per bank row scores the gathered
        # patches, adds the uniform prior, and runs the whole epilogue —
        # the (B, P) log-weight array never touches HBM.  Fused epilogue:
        # likelihood first, then one kernel pass per row for weights +
        # ancestors + stats (the CDF never leaves VMEM).  Composed: banked
        # normalize with in-pass ESS sums, ancestors drawn by the separate
        # resample chain below — bitwise the same results on every path.
        if self._fused_step_banked is not None:
            fstep = spec.step_fusion
            patches = jax.vmap(fstep.gather, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            )
            prior = jnp.broadcast_to(
                neg_log_count(num_particles, cdt), (nb,)
            )
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = (
                self._fused_step_banked(
                    k_res, patches, fstep.model, prior, policy
                )
            )
        elif self._fused_banked is not None:
            log_lik = jax.vmap(spec.loglik, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            ).astype(cdt)
            log_w = state.log_weights + log_lik
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = (
                self._fused_banked(k_res, log_w, policy)
            )
        else:
            log_lik = jax.vmap(spec.loglik, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            ).astype(cdt)
            log_w = state.log_weights + log_lik
            weights, log_z, max_lw, sum_w, sum_w2 = (
                self._normalize_stats_banked(log_w)
            )
            ancestors = self._resample_banked(k_res, weights, policy)
        prev_lse = stability.logsumexp(
            state.log_weights.astype(policy.accum_dtype), axis=-1
        )
        log_z_inc = log_z - prev_lse
        w_accum = weights.astype(policy.accum_dtype)
        adt = policy.accum_dtype
        ess = jnp.square(sum_w.astype(adt)) / sum_w2.astype(adt)

        if spec.summary is not None:
            estimate = jax.vmap(spec.summary)(particles, w_accum)
        else:
            estimate = jax.vmap(
                lambda p, w: _weighted_mean(p, w, policy.accum_dtype)
            )(particles, weights)

        # resampling gather, per-slot trigger.  Uniform reset rows size off
        # the carried weights: log_w never materializes on the fused-step
        # path.
        gather = spec.gather or resampling.gather_ancestors
        uniform = jnp.full_like(
            state.log_weights, -jnp.log(float(num_particles))
        )
        if self.config.ess_threshold >= 1.0:
            do_resample = jnp.ones((nb,), bool)
            new_particles = jax.vmap(gather)(particles, ancestors)
            new_log_w = uniform
        else:
            # Slots select per-row between the resampled and kept branches;
            # both are computed (select semantics, as under any vmapped
            # cond) — values match ParticleFilter's cond branches exactly.
            do_resample = ess < self.config.ess_threshold * num_particles
            res_particles = jax.vmap(gather)(particles, ancestors)
            kept_log_w = jnp.log(w_accum).astype(cdt)
            new_log_w = jnp.where(do_resample[:, None], uniform, kept_log_w)
            new_particles = jax.tree.map(
                lambda r, k: jnp.where(
                    do_resample.reshape((nb,) + (1,) * (r.ndim - 1)), r, k
                ),
                res_particles,
                particles,
            )

        new_state = FilterState(
            particles=new_particles,
            log_weights=new_log_w,
            step=state.step + 1,
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=log_z_inc,
            resampled=do_resample,
            max_loglik=max_lw,
        )
        return new_state, out

    def _step_masked(self, state, observations, keys, shared_obs):
        """One frame of a ragged bank: per-slot active prefixes via masking.

        Inactive lanes still propagate (static shapes), but their
        log-weights are pinned to -inf before normalization, so they carry
        weight exactly 0 through ESS, estimates, and the evidence, and
        ancestors are drawn over the active prefix only (count-aware
        systematic grid).  With every slot full-width this is bitwise the
        dense step: every mask selects the unmasked value and the
        count-aware grids divide by the same fp32 counts.
        """
        spec, policy = self.spec, self.policy
        cdt = policy.compute_dtype
        nb, num_particles = state.log_weights.shape
        n_act = state.n_active
        lane = jnp.arange(num_particles)
        active = lane[None, :] < n_act[:, None]
        neg_inf = jnp.asarray(-jnp.inf, cdt)
        split = jax.vmap(jax.random.split)(keys)
        k_prop, k_res = split[:, 0], split[:, 1]
        obs_ax = None if shared_obs else 0

        # 1. propagation — every lane steps (static shapes); the mask below
        # keeps the inactive ones out of every statistic.
        particles = jax.vmap(spec.transition)(
            k_prop, state.particles, state.step
        )

        # 2-6. likelihood, then the masked weight epilogue, with inactive
        # lanes pinned to -inf before they reach any statistic (a junk
        # lane's -inf carry plus a +inf log-lik would otherwise produce
        # nan).  Full-step fusion: the count-aware streaming kernel takes
        # each slot's stored ``log_uniform`` prior and masks by position
        # inside the pass — junk patch lanes never matter.  Fused
        # epilogue: likelihood + pre-mask first, then the count-aware
        # fused kernel.  Composed: masked normalize with in-pass ESS sums
        # + the masked resample chain.  Bitwise the same on every path.
        if self._fused_step_masked is not None:
            fstep = spec.step_fusion
            patches = jax.vmap(fstep.gather, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            )
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = (
                self._fused_step_masked(
                    k_res, patches, fstep.model,
                    state.log_uniform, policy, n_act,
                )
            )
        elif self._fused_masked is not None:
            log_lik = jax.vmap(spec.loglik, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            ).astype(cdt)
            log_w = jnp.where(active, state.log_weights + log_lik, neg_inf)
            weights, ancestors, log_z, max_lw, sum_w, sum_w2 = (
                self._fused_masked(k_res, log_w, policy, n_act)
            )
        else:
            log_lik = jax.vmap(spec.loglik, in_axes=(0, obs_ax, 0))(
                particles, observations, state.step
            ).astype(cdt)
            log_w = jnp.where(active, state.log_weights + log_lik, neg_inf)
            weights, log_z, max_lw, sum_w, sum_w2 = (
                self._normalize_stats_masked(log_w, n_act)
            )
            ancestors = self._resample_masked(k_res, weights, policy, n_act)
        prev_lse = stability.logsumexp(
            state.log_weights.astype(policy.accum_dtype), axis=-1
        )
        log_z_inc = log_z - prev_lse
        w_accum = weights.astype(policy.accum_dtype)
        adt = policy.accum_dtype
        ess = jnp.square(sum_w.astype(adt)) / sum_w2.astype(adt)

        if spec.summary is not None:
            estimate = jax.vmap(spec.summary)(particles, w_accum)
        else:
            estimate = jax.vmap(
                lambda p, w: _weighted_mean(p, w, policy.accum_dtype)
            )(particles, weights)

        # resampling over the active prefix; the reset row reuses the
        # per-slot stored uniform value (see FilterState.log_uniform).
        gather = spec.gather or resampling.gather_ancestors
        uniform = jnp.where(
            active,
            jnp.broadcast_to(
                state.log_uniform[:, None], (nb, num_particles)
            ).astype(cdt),
            neg_inf,
        )
        if self.config.ess_threshold >= 1.0:
            do_resample = jnp.ones((nb,), bool)
            new_particles = jax.vmap(gather)(particles, ancestors)
            new_log_w = uniform
        else:
            # Per-slot trigger against the slot's own budget, not the lane
            # width: ESS can never exceed n_active.  Compare in ess's own
            # dtype, as the dense path's weak Python scalar does.
            do_resample = ess < (
                self.config.ess_threshold * n_act.astype(jnp.float32)
            ).astype(ess.dtype)
            res_particles = jax.vmap(gather)(particles, ancestors)
            kept_log_w = jnp.log(w_accum).astype(cdt)  # -inf at w=0
            new_log_w = jnp.where(do_resample[:, None], uniform, kept_log_w)
            new_particles = jax.tree.map(
                lambda r, k: jnp.where(
                    do_resample.reshape((nb,) + (1,) * (r.ndim - 1)), r, k
                ),
                res_particles,
                particles,
            )

        new_state = FilterState(
            particles=new_particles,
            log_weights=new_log_w,
            step=state.step + 1,
            n_active=n_act,
            log_uniform=state.log_uniform,
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=log_z_inc,
            resampled=do_resample,
            max_loglik=max_lw,
        )
        return new_state, out

    def run(
        self,
        key: jax.Array,
        observations: Any,
        num_particles: int,
        *,
        shared_obs: bool = True,
        n_active: Any = None,
    ) -> tuple[FilterState, FilterOutput]:
        """Filter a whole sequence under ``lax.scan``, all slots at once.

        observations: pytree with a leading time axis — shared across slots
        by default (multi-object tracking over one frame stream); pass
        ``shared_obs=False`` for per-slot streams with leading (T, B) axes.
        ``n_active``: optional (B,) per-slot active counts (ragged bank).
        Returns (final state, per-step outputs stacked over (T, B, ...)).
        """
        k_init, k_run = jax.random.split(key)
        state0 = self.init(k_init, num_particles, n_active)
        num_steps = jax.tree.leaves(observations)[0].shape[0]
        # (T, B) keys; for B == 1 this is exactly ParticleFilter.run's
        # split(k_run, T) key path, reshaped.
        step_keys = jax.random.split(
            k_run, num_steps * self.num_slots
        ).reshape(num_steps, self.num_slots)

        def body(state, xs):
            obs, ks = xs
            return self.step(state, obs, ks, shared_obs=shared_obs)

        return jax.lax.scan(body, state0, (observations, step_keys))

    @functools.cached_property
    def jit_step(self):
        """Per-slot-observation step, jit-compiled once per bank instance."""
        return jax.jit(functools.partial(self.step, shared_obs=False))

    @functools.cached_property
    def jit_step_shared(self):
        """Shared-observation step, jit-compiled once per bank instance."""
        return jax.jit(functools.partial(self.step, shared_obs=True))

    @functools.cached_property
    def jit_init_slot(self):
        """``init_slot`` jit-compiled once; slot index stays traced."""
        return jax.jit(self.init_slot)

    # Donated variants: the input state's buffers are donated to the call,
    # so a carried-state loop (the continuous-batching scheduler tick)
    # reuses the bank's particle/weight/cache memory in place instead of
    # copying it every step.  The passed-in state is *consumed* — its
    # arrays are invalidated at dispatch — so only use these where the old
    # state is never touched again (the scheduler's sync loop; NOT the
    # async loop, which must read the pre-step state while the step runs).

    @functools.cached_property
    def jit_step_donated(self):
        """:attr:`jit_step` with the state argument donated."""
        return jax.jit(
            functools.partial(self.step, shared_obs=False),
            donate_argnums=(0,),
        )

    @functools.cached_property
    def jit_step_shared_donated(self):
        """:attr:`jit_step_shared` with the state argument donated."""
        return jax.jit(
            functools.partial(self.step, shared_obs=True),
            donate_argnums=(0,),
        )

    @functools.cached_property
    def jit_init_slot_donated(self):
        """:attr:`jit_init_slot` with the state argument donated — a slot
        admission rewrites one row in place instead of copying the bank."""
        return jax.jit(self.init_slot, donate_argnums=(0,))

    @functools.cached_property
    def jit_resize_slot(self):
        """``resize_slot`` jit-compiled once; slot and count stay traced,
        so budget transitions never recompile."""
        return jax.jit(self.resize_slot)

    @functools.cached_property
    def jit_resize_slot_donated(self):
        """:attr:`jit_resize_slot` with the state argument donated — a
        budget switch rewrites the slot's rows in place."""
        return jax.jit(self.resize_slot, donate_argnums=(0,))

    @functools.cached_property
    def jit_reseed_slot(self):
        """``reseed_slot`` jit-compiled once; slot and count stay traced."""
        return jax.jit(self.reseed_slot)

    @functools.cached_property
    def jit_reseed_slot_donated(self):
        """:attr:`jit_reseed_slot` with the state argument donated — the
        recovery re-seed rewrites the slot's rows in place."""
        return jax.jit(self.reseed_slot, donate_argnums=(0,))

    @functools.cached_property
    def jit_export_slot(self):
        """``export_slot`` jit-compiled once — *never* donated: the source
        bank keeps serving from the exported state."""
        return jax.jit(self.export_slot)

    @functools.cached_property
    def jit_import_slot(self):
        """``import_slot`` jit-compiled once; slot/count/step stay traced
        (one compile per source-width × destination-width pair)."""
        return jax.jit(self.import_slot)

    @functools.cached_property
    def jit_import_slot_donated(self):
        """:attr:`jit_import_slot` with the destination state donated — a
        migration admit rewrites the receiving slot's rows in place."""
        return jax.jit(self.import_slot, donate_argnums=(0,))

    # -- internals ----------------------------------------------------------

    def _normalize_banked(self, log_w: jax.Array):
        if not self.policy.stable_weighting:
            # Paper's naive path: direct exponentiation, overflow and all.
            w, log_z = stability.normalize_log_weights(log_w, stable=False)
            return w, log_z, jnp.max(log_w, axis=-1)
        return self._normalize_banked_impl(log_w, self.policy)

    def _normalize_masked(self, log_w: jax.Array, n_active: jax.Array):
        if not self.policy.stable_weighting:
            # Naive path: exp(-inf) = 0, so masked lanes drop out of the
            # direct-exponentiation sums exactly as in the stable path.
            w, log_z = stability.normalize_log_weights(log_w, stable=False)
            return w, log_z, jnp.max(log_w, axis=-1)
        return self._normalize_masked_impl(log_w, n_active, self.policy)

    def _normalize_stats_banked(self, log_w: jax.Array):
        """Banked normalize + in-pass Kish sums: (w, log_z, m, sw, sw2)."""
        if not self.policy.stable_weighting:
            w, log_z, m = self._normalize_banked(log_w)
            sum_w, sum_w2 = _kish_sums(w, self.policy.accum_dtype)
            return w, log_z, m, sum_w, sum_w2
        return self._normalize_stats_banked_impl(log_w, self.policy)

    def _normalize_stats_masked(self, log_w: jax.Array, n_active: jax.Array):
        """Masked twin of :meth:`_normalize_stats_banked` (inactive lanes
        contribute exactly 0 to both sums on every path)."""
        if not self.policy.stable_weighting:
            w, log_z, m = self._normalize_masked(log_w, n_active)
            sum_w, sum_w2 = _kish_sums(w, self.policy.accum_dtype)
            return w, log_z, m, sum_w, sum_w2
        return self._normalize_stats_masked_impl(log_w, n_active, self.policy)

    def _dist_step(self, shared_obs: bool, ragged: bool = False):
        """The shard_map'd banked step, built once per (obs, ragged) mode."""
        fn = self._dist_steps.get((shared_obs, ragged))
        if fn is None:
            from repro.core import distributed

            local_resample = None
            local_resample_masked = None
            fused_finalize = None
            fused_finalize_masked = None
            fused_step_stats = None
            fused_step_stats_masked = None
            if self.config.scheme == "local":
                local_resample = self.backend.ancestors_from_u0_banked.get(
                    self.config.resampler
                )
                local_resample_masked = (
                    self.backend.ancestors_from_u0_masked.get(
                        self.config.resampler
                    )
                )
                if (
                    self.config.fused_epilogue is not False
                    and self.policy.stable_weighting
                ):
                    # Shard-local fused tail: weights + ancestors_from_u0
                    # in one pass once the LSE merge lands.
                    fused_finalize = self.backend.fused_finalize_banked.get(
                        self.config.resampler
                    )
                    fused_finalize_masked = (
                        self.backend.fused_finalize_masked.get(
                            self.config.resampler
                        )
                    )
                fstep = self.spec.step_fusion
                if (
                    self.config.fused_step is not False
                    and fstep is not None
                    and self.policy.stable_weighting
                    and (
                        fstep.backend is None
                        or fstep.backend == self.config.backend
                    )
                    and fused_finalize is not None
                ):
                    # Shard-local fused head: likelihood + prior add +
                    # online-LSE stats in one pass; the merged LSE then
                    # feeds the fused finalize tail, so the shard's
                    # log-weights stream straight from patches to
                    # ancestors.  Only worthwhile (and only bitwise-
                    # plumbed) in front of the finalize tail, hence the
                    # gate on it.
                    fused_step_stats = (
                        self.backend.fused_step_finalize_banked.get(
                            self.config.resampler
                        )
                    )
                    fused_step_stats_masked = (
                        self.backend.fused_step_finalize_masked.get(
                            self.config.resampler
                        )
                    )
            fn = distributed.make_dist_bank_step(
                self.spec,
                self.policy,
                self._dist_cfg,
                shared_obs=shared_obs,
                ragged=ragged,
                local_stats=self.backend.local_stats_banked,
                local_stats_masked=self.backend.local_stats_masked,
                local_resample=local_resample,
                local_resample_masked=local_resample_masked,
                fused_finalize=fused_finalize,
                fused_finalize_masked=fused_finalize_masked,
                fused_step_stats=fused_step_stats,
                fused_step_stats_masked=fused_step_stats_masked,
            )
            self._dist_steps[(shared_obs, ragged)] = fn
        return fn

    def _step_distributed(self, state, observations, keys, shared_obs):
        # Both distributed schemes resample every frame; the evidence
        # increment closes over the (globally sharded) pre-step weights.
        prev_lse = stability.logsumexp(
            state.log_weights.astype(self.policy.accum_dtype), axis=-1
        )
        ragged = state.n_active is not None
        if ragged:
            particles, log_w, step, estimate, ess, lse, max_lw = (
                self._dist_step(shared_obs, ragged=True)(
                    state.particles,
                    state.log_weights,
                    state.step,
                    observations,
                    keys,
                    state.n_active,
                    state.log_uniform,
                )
            )
        else:
            particles, log_w, step, estimate, ess, lse, max_lw = (
                self._dist_step(shared_obs)(
                    state.particles,
                    state.log_weights,
                    state.step,
                    observations,
                    keys,
                )
            )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=lse - prev_lse,
            # Geometry from the state, not self.num_slots: the jitted step
            # is shared across sibling banks (see :meth:`sibling`), which
            # may carry different slot counts.
            resampled=jnp.ones((state.log_weights.shape[0],), bool),
            max_loglik=max_lw,
        )
        return FilterState(
            particles,
            log_w,
            step,
            n_active=state.n_active,
            log_uniform=state.log_uniform,
        ), out

    def _check_mesh_divisibility(self, num_particles: int) -> None:
        cfg = self._dist_cfg
        shape = dict(zip(cfg.mesh.axis_names, cfg.mesh.devices.shape))
        d_bank = shape[cfg.bank_axis]
        d_part = cfg.num_shards
        if self.num_slots % d_bank:
            raise ValueError(
                f"num_slots={self.num_slots} must divide by the "
                f"{cfg.bank_axis!r} axis size {d_bank}"
            )
        if num_particles % d_part:
            raise ValueError(
                f"num_particles={num_particles} must divide by the "
                f"particle axes {cfg.axes} size {d_part}"
            )

    def _shard_state(self, state: FilterState) -> FilterState:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        cfg = self._dist_cfg
        mesh = self.config.mesh
        sh_bp = NamedSharding(mesh, P(cfg.bank_axis, cfg.axes))
        sh_b = NamedSharding(mesh, P(cfg.bank_axis))

        def place(x, sh):
            if isinstance(x, jax.core.Tracer):
                return jax.lax.with_sharding_constraint(x, sh)
            return jax.device_put(x, sh)

        paxes = self.spec.particle_axes
        if paxes is None:
            particles = jax.tree.map(
                lambda x: place(x, sh_bp), state.particles
            )
        else:
            # Leaves whose particle axis is not leading (LM caches) shard
            # on their own particle dimension.
            particles = jax.tree.map(
                lambda x, ax: place(
                    x,
                    NamedSharding(
                        mesh,
                        P(cfg.bank_axis, *([None] * ax), cfg.axes),
                    ),
                ),
                state.particles,
                paxes,
            )
        return FilterState(
            particles=particles,
            log_weights=place(state.log_weights, sh_bp),
            step=place(state.step, sh_b),
            n_active=None
            if state.n_active is None
            else place(state.n_active, sh_b),
            log_uniform=None
            if state.log_uniform is None
            else place(state.log_uniform, sh_b),
        )


def _weighted_mean(particles, weights, adt):
    # Scale-invariant: divide by the *actual* weight sum.  In 16-bit,
    # exp(log_w - lse) does not sum to 1 (bf16 resolves log-weights ~300
    # only to ±2, i.e. a factor e^2 on each weight) — trusting the LSE to
    # normalize inflates the estimate off the image.  Lesson recorded in
    # EXPERIMENTS.md §Paper-validation.
    w = weights.astype(adt)
    total = jnp.sum(w)

    def _mean(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x  # integer states (e.g. token ids) are not averaged
        wx = w.reshape(w.shape + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(adt) * wx, axis=0) / total

    return jax.tree.map(_mean, particles)
