"""The particle-filter engine: one object, every execution axis a config key.

The paper's central move is a filter *generic over precision*
(``particleFilter<double/float/half>``); this module generalizes the idea to
every axis the repo has grown since: numeric policy, kernel backend,
resampling scheme, ESS threshold, and particle distribution over a device
mesh are all fields of one :class:`FilterConfig`, and one
:class:`ParticleFilter` executes any combination:

    flt = ParticleFilter(spec, FilterConfig(policy="bf16", backend="pallas"))
    state = flt.init(key, num_particles)
    state, out = flt.step(state, observation, key)      # one frame
    final, outs = flt.run(key, observations, num_particles)   # lax.scan
    for state, out in flt.stream(key, obs_iter, n): ...       # serving loop

Backends and resamplers are *registries* (:func:`register_backend`,
:func:`repro.core.resampling.register_resampler`), mirroring
``precision.register_policy``: the pure-jnp reference forms, the fused
Pallas kernel chain, and any future accelerator path are looked up by name,
never imported by the call site.  The multi-device filter is not a separate
entry point either — ``FilterConfig(mesh=..., scheme="local")`` routes the
same ``init``/``step``/``run`` through the shard_map step of
``repro.core.distributed`` (exact / local-RNA resampling schemes).

``pf_step`` / ``pf_scan`` / ``track`` remain as deprecation shims that
forward here; the jnp backend is bit-identical to the legacy functions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import resampling, stability
from repro.core.filter import FilterOutput, FilterState, SMCSpec
from repro.core.precision import PrecisionPolicy, get_policy

__all__ = [
    "Backend",
    "BACKENDS",
    "FilterConfig",
    "ParticleFilter",
    "get_backend",
    "register_backend",
]


# ---------------------------------------------------------------------------
# Backend registry


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution backend for the per-frame kernel chain.

    normalize:  (log_w, policy) -> (weights, log_z, max_log_w) — the paper's
                max-finding + weighting + normalizing stages (Eq. 5).
    resamplers: per-resampler-name overrides ``(key, weights, policy) ->
                ancestors``; names without an override fall back to the
                registered pure-jnp resampler.
    """

    name: str
    normalize: Callable[[jax.Array, PrecisionPolicy], tuple]
    resamplers: Mapping[str, Callable] = dataclasses.field(
        default_factory=dict
    )


BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown filter backend {name!r}; have {sorted(BACKENDS)}"
        ) from None


def _jnp_normalize(log_w: jax.Array, policy: PrecisionPolicy):
    m = jnp.max(log_w)
    lse = stability.logsumexp(log_w.astype(policy.accum_dtype), axis=-1)
    w = jnp.exp(log_w.astype(policy.accum_dtype) - lse).astype(log_w.dtype)
    return w, lse, m


def _pallas_normalize(log_w: jax.Array, policy: PrecisionPolicy):
    del policy  # the fused kernel carries its own fp32 accumulators
    from repro.kernels.logsumexp import ops as lse_ops

    w, m, lse = lse_ops.normalize_weights(log_w)
    return w, lse, m


def _pallas_systematic(key: jax.Array, weights: jax.Array, policy):
    del policy
    from repro.kernels.resample import ops as res_ops

    return res_ops.systematic_resample(key, weights)


register_backend(Backend("jnp", _jnp_normalize))
register_backend(
    Backend(
        "pallas",
        _pallas_normalize,
        resamplers={"systematic": _pallas_systematic},
    )
)


# ---------------------------------------------------------------------------
# Config


@dataclasses.dataclass(frozen=True)
class FilterConfig:
    """Everything about *how* a filter executes (the model lives in SMCSpec).

    policy / backend / resampler are registry names (policy may also be a
    :class:`PrecisionPolicy` instance).  Setting ``mesh`` shards particles
    over the named mesh ``axis`` and switches resampling to the distributed
    ``scheme`` ("exact" global systematic, or "local" RNA with periodic ring
    exchange — see ``repro.core.distributed``).
    """

    policy: str | PrecisionPolicy = "fp32"
    backend: str = "jnp"
    resampler: str = "systematic"
    ess_threshold: float = 1.0  # resample when ESS < threshold * P
    # Distribution spec (None -> single placement).
    mesh: Any = None
    axis: str | tuple[str, ...] = "data"
    scheme: str = "exact"
    exchange_every: int = 4
    exchange_frac: float = 0.25

    def with_(self, **kw: Any) -> "FilterConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Engine


class ParticleFilter:
    """A configured filter over one SMC model.

    Construction resolves every registry name (raising immediately on
    unknown policies/backends/resamplers/schemes); the step methods are pure
    jax functions of their array arguments, safe under jit/scan.
    """

    def __init__(self, spec: SMCSpec, config: FilterConfig | None = None):
        self.spec = spec
        self.config = config = config or FilterConfig()
        self.policy = (
            get_policy(config.policy)
            if isinstance(config.policy, str)
            else config.policy
        )
        self.backend = get_backend(config.backend)
        base_resampler = resampling.get_resampler(config.resampler)
        override = self.backend.resamplers.get(config.resampler)
        self._resample = override or base_resampler

        self._dist_step = None
        if config.mesh is not None:
            from repro.core import distributed

            dist_cfg = distributed.DistributedConfig(
                mesh=config.mesh,
                axis=config.axis,
                scheme=config.scheme,
                exchange_every=config.exchange_every,
                exchange_frac=config.exchange_frac,
            )
            self._dist_cfg = dist_cfg
            self._dist_step = distributed.make_dist_pf_step(
                spec, self.policy, dist_cfg
            )

    # -- lifecycle ----------------------------------------------------------

    def init(self, key: jax.Array, num_particles: int) -> FilterState:
        """Draw the initial particle cloud with uniform weights."""
        particles = self.spec.init(key, num_particles)
        particles = jax.tree.map(
            lambda x: x.astype(self.policy.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.inexact)
            else x,
            particles,
        )
        log_w = jnp.full(
            (num_particles,),
            -jnp.log(float(num_particles)),
            self.policy.compute_dtype,
        )
        state = FilterState(particles, log_w, jnp.asarray(0, jnp.int32))
        if self._dist_step is not None:
            state = self._shard_state(state)
        return state

    def step(
        self, state: FilterState, observation: Any, key: jax.Array
    ) -> tuple[FilterState, FilterOutput]:
        """One frame: propagate → weight → normalize → estimate → resample."""
        if self._dist_step is not None:
            return self._step_distributed(state, observation, key)
        return self._step_local(state, observation, key)

    def run(
        self, key: jax.Array, observations: Any, num_particles: int
    ) -> tuple[FilterState, FilterOutput]:
        """Filter a whole sequence under ``lax.scan``.

        observations: pytree with a leading time axis (e.g. video (T, H, W)).
        Returns (final state, stacked per-step outputs).
        """
        k_init, k_run = jax.random.split(key)
        state0 = self.init(k_init, num_particles)
        num_steps = jax.tree.leaves(observations)[0].shape[0]
        step_keys = jax.random.split(k_run, num_steps)

        def body(state, xs):
            obs, k = xs
            return self.step(state, obs, k)

        return jax.lax.scan(body, state0, (observations, step_keys))

    def stream(
        self,
        key: jax.Array,
        observations: Any,
        num_particles: int,
        *,
        jit: bool = True,
    ):
        """Streaming filter for serving: yields (state, output) per frame.

        ``observations`` is any iterable — frames arriving from a queue, an
        endless generator, decode-step indices.  Per-step keys derive by
        ``fold_in`` of the step index so the stream never needs to know its
        length (unlike :meth:`run`, whose key path matches the legacy scan).
        """
        k_init, k_run = jax.random.split(key)
        state = self.init(k_init, num_particles)
        step = self.jit_step if jit else self.step
        for i, obs in enumerate(observations):
            state, out = step(state, obs, jax.random.fold_in(k_run, i))
            yield state, out

    @functools.cached_property
    def jit_step(self):
        """The step function jit-compiled once per engine instance."""
        return jax.jit(self.step)

    # -- internals ----------------------------------------------------------

    def _normalize(self, log_w: jax.Array):
        if not self.policy.stable_weighting:
            # Paper's naive path: direct exponentiation, overflow and all.
            w, log_z = stability.normalize_log_weights(log_w, stable=False)
            return w, log_z, jnp.max(log_w)
        return self.backend.normalize(log_w, self.policy)

    def _step_local(self, state, observation, key):
        spec, policy = self.spec, self.policy
        cdt = policy.compute_dtype
        k_prop, k_res = jax.random.split(key)
        num_particles = state.log_weights.shape[0]

        # 1. propagation (paper kernel 1)
        particles = spec.transition(k_prop, state.particles, state.step)

        # 2. likelihood (kernel 2)
        log_lik = spec.loglik(particles, observation, state.step).astype(cdt)
        log_w = state.log_weights + log_lik

        # 3-5. max-find + weighting + normalizing (kernels 3-5; fused on the
        # pallas backend)
        weights, log_z, max_lw = self._normalize(log_w)
        prev_lse = stability.logsumexp(
            state.log_weights.astype(policy.accum_dtype), axis=-1
        )
        log_z_inc = log_z - prev_lse
        w_accum = weights.astype(policy.accum_dtype)
        ess = stability.effective_sample_size(w_accum)

        if spec.summary is not None:
            estimate = spec.summary(particles, w_accum)
        else:
            estimate = _weighted_mean(particles, weights, policy.accum_dtype)

        # 6. resampling (kernel 6)
        do_resample = (
            ess < self.config.ess_threshold * num_particles + 0.5
        )  # ==1.0 -> always
        gather = self.spec.gather or resampling.gather_ancestors

        def _resampled():
            ancestors = self._resample(k_res, weights, policy)
            new_particles = gather(particles, ancestors)
            uniform = jnp.full_like(log_w, -jnp.log(float(num_particles)))
            return new_particles, uniform

        def _kept():
            return particles, jnp.log(
                weights.astype(policy.accum_dtype)
            ).astype(log_w.dtype)

        new_particles, new_log_w = jax.lax.cond(
            do_resample, _resampled, _kept
        )

        new_state = FilterState(
            particles=new_particles,
            log_weights=new_log_w,
            step=state.step + 1,
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=log_z_inc,
            resampled=do_resample,
            max_loglik=max_lw,
        )
        return new_state, out

    def _step_distributed(self, state, observation, key):
        # Both distributed schemes resample every frame; the evidence
        # increment closes over the (globally sharded) pre-step weights.
        prev_lse = stability.logsumexp(
            state.log_weights.astype(self.policy.accum_dtype), axis=-1
        )
        particles, log_w, step, estimate, ess, lse, max_lw = self._dist_step(
            state.particles, state.log_weights, state.step, observation, key
        )
        out = FilterOutput(
            estimate=estimate,
            ess=ess,
            log_z_inc=lse - prev_lse,
            resampled=jnp.asarray(True),
            max_loglik=max_lw,
        )
        return FilterState(particles, log_w, step), out

    def _shard_state(self, state: FilterState) -> FilterState:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        sh = NamedSharding(self.config.mesh, P(self._dist_cfg.axes))

        def place(x):
            if isinstance(x, jax.core.Tracer):
                return jax.lax.with_sharding_constraint(x, sh)
            return jax.device_put(x, sh)

        return FilterState(
            particles=jax.tree.map(place, state.particles),
            log_weights=place(state.log_weights),
            step=state.step,
        )


def _weighted_mean(particles, weights, adt):
    # Scale-invariant: divide by the *actual* weight sum.  In 16-bit,
    # exp(log_w - lse) does not sum to 1 (bf16 resolves log-weights ~300
    # only to ±2, i.e. a factor e^2 on each weight) — trusting the LSE to
    # normalize inflates the estimate off the image.  Lesson recorded in
    # EXPERIMENTS.md §Paper-validation.
    w = weights.astype(adt)
    total = jnp.sum(w)

    def _mean(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x  # integer states (e.g. token ids) are not averaged
        wx = w.reshape(w.shape + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(adt) * wx, axis=0) / total

    return jax.tree.map(_mean, particles)
