"""Shared neural layers (pure functions over param dicts).

All matmuls run in the policy compute dtype with fp32 accumulation
(``preferred_element_type``) — the MXU-native landing of the paper's
"accumulate wider than you store" discipline; softmax/normalization
reductions likewise run in the accum dtype via ``stability.stable_softmax``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

__all__ = [
    "rmsnorm",
    "rmsnorm_spec",
    "dense",
    "dense_spec",
    "mlp",
    "mlp_spec",
    "embed_spec",
    "embed_lookup",
    "rope",
    "ACTS",
]

ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def rmsnorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def dense_spec(
    d_in: int, d_out: int, logical=("embed", "mlp"), bias: bool = False,
    init: str = "normal", scale: float = 1.0,
) -> dict:
    spec = {"w": ParamSpec((d_in, d_out), logical, init=init, scale=scale)}
    if bias:
        spec["b"] = ParamSpec((d_out,), (logical[-1],), init="zeros")
    return spec


def dense(params: dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum(
        "...d,df->...f",
        x,
        params["w"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def mlp_spec(d: int, d_ff: int, bias: bool = False) -> dict:
    """Gated (SwiGLU-style) feed-forward."""
    return {
        "wi_gate": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wi_up": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d), ("mlp", "embed_out")),
        **({"b": ParamSpec((d,), ("embed_out",), init="zeros")} if bias else {}),
    }


def mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    f = ACTS[act]
    gate = jnp.einsum(
        "...d,df->...f", x, params["wi_gate"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    up = jnp.einsum(
        "...d,df->...f", x, params["wi_up"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    h = (f(gate) * up).astype(x.dtype)
    y = jnp.einsum(
        "...f,fd->...d", h, params["wo"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embed_spec(vocab: int, d: int) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed_lookup(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 10_000.0
) -> jax.Array:
    """Rotary embedding over the last axis. x: (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half)
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)
