"""Mamba2 (SSD) layer — chunked-parallel training, O(1)-state decode.

The SSD recurrence h_t = exp(A·dt_t)·h_t-1 + dt_t·B_t⊗x_t is evaluated in
chunks: intra-chunk terms use masked decay matrices (a prefix-sum — the same
cumulative structure as the particle filter's CDF kernel), inter-chunk terms
carry the (heads, state, head_dim) tensor through ``lax.scan``.  All decay
arithmetic (cumsums of log-decays, their exponentials) runs in fp32
regardless of the compute dtype — the paper's stability discipline applied
to the SSM: 16-bit cumulative products of near-1 decays lose the state.

Decode carries (conv window, ssm state) per layer; no KV cache, which is
why the hybrid/ssm archs are the ones that run the 500k-token cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

__all__ = ["ssm_spec", "ssm_forward", "ssm_decode", "init_ssm_cache"]


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def ssm_spec(cfg) -> dict:
    d = cfg.d_model
    d_inner, n_heads, n, p = _dims(cfg)
    conv_dim = d_inner + 2 * n  # x, B, C share the causal conv
    return {
        "in_proj": ParamSpec(
            (d, 2 * d_inner + 2 * n + n_heads), ("embed", "ssm_inner")
        ),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((n_heads,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((n_heads,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamSpec((n_heads,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed_out")),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, n_heads, n, p = _dims(cfg)
    z, xs, b, c, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1,
    )
    return z, xs, b, c, dt


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv along time. xbc: (B, T, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :].astype(xbc.dtype)
        for i in range(k)
    )
    return jax.nn.silu(out + bias.astype(xbc.dtype))


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) with M[i,j]=sum_{j<p<=i} a_p, -inf above diag."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum_{p in (j, i]}
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssm_forward(
    params: dict, x: jax.Array, cfg, *, chunk: int = 128
) -> jax.Array:
    """Full-sequence SSD. x: (B, T, d_model) -> same."""
    bsz, t, _ = x.shape
    d_inner, n_heads, n, p = _dims(cfg)
    cdt = x.dtype

    zxbcdt = jnp.einsum(
        "btd,de->bte", x, params["in_proj"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    z, xs, b, c, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(
        jnp.concatenate([xs, b, c], axis=-1), params["conv_w"], params["conv_b"]
    )
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, T, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,) negative
    adt = a * dt  # (B, T, H) log-decay per step

    import math

    xh = xs.reshape(bsz, t, n_heads, p)
    chunk = math.gcd(t, chunk)
    nchunks = t // chunk

    def reshape_c(v):
        return v.reshape((bsz, nchunks, chunk) + v.shape[2:])

    xc, bc_, cc, adtc, dtc = map(reshape_c, (xh, b, c, adt, dt))

    def chunk_step(h, inputs):
        xk, bk, ck, ak, dk = inputs  # (B, Q, ...) for one chunk
        # decay algebra in fp32
        seg = _segsum(jnp.moveaxis(ak, -1, 1))  # (B, H, Q, Q)
        decay = jnp.exp(seg)
        g = jnp.einsum(
            "bqn,bkn->bqk", ck.astype(jnp.float32), bk.astype(jnp.float32)
        )  # (B, Q, Q) shared across heads (n_groups=1)
        m = g[:, None] * decay  # (B, H, Q, Q)
        xdt = xk.astype(jnp.float32) * dk[..., None]  # (B, Q, H, P)
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", m, xdt)

        cum = jnp.cumsum(jnp.moveaxis(ak, -1, 1), axis=-1)  # (B, H, Q)
        # inter-chunk: contribution of the carried state
        out_decay = jnp.exp(cum)  # (B, H, Q)
        y_inter = jnp.einsum(
            "bqn,bhnp,bhq->bqhp", ck.astype(jnp.float32), h, out_decay
        )
        # state update
        rem = jnp.exp(cum[..., -1:] - cum)  # decay from step q to chunk end
        h_in = jnp.einsum("bqn,bqhp,bhq->bhnp", bk.astype(jnp.float32), xdt, rem)
        h_new = h * jnp.exp(cum[..., -1])[..., None, None] + h_in
        return h_new, (y_intra + y_inter).astype(cdt)

    h0 = jnp.zeros((bsz, n_heads, n, p), jnp.float32)
    xcs = jnp.moveaxis(xc, 1, 0)
    _, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            xcs,
            jnp.moveaxis(bc_, 1, 0),
            jnp.moveaxis(cc, 1, 0),
            jnp.moveaxis(adtc, 1, 0),
            jnp.moveaxis(dtc, 1, 0),
        ),
        unroll=True if cfg.unroll_scans else 1,
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, n_heads, p)
    y = y + xh * params["d_skip"].astype(cdt)[None, None, :, None]
    y = y.reshape(bsz, t, d_inner)

    # gated RMS norm (mamba2 style) then out-projection
    y = _gated_norm(y, z, params["norm"])
    return jnp.einsum(
        "bti,id->btd", y, params["out_proj"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)


def _gated_norm(y, z, scale, eps=1e-5):
    cdt = y.dtype
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        cdt
    )


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    d_inner, n_heads, n, p = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, n_heads, n, p), jnp.float32),
    }


def ssm_cache_spec(cfg, batch: int) -> dict:
    from repro.models.params import ParamSpec

    d_inner, n_heads, n, p = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": ParamSpec(
            (batch, cfg.ssm_conv - 1, conv_dim),
            ("batch", None, "ssm_inner"),
            init="zeros",
        ),
        # recurrent state accumulates in fp32 (stability discipline)
        "state": ParamSpec(
            (batch, n_heads, n, p),
            ("batch", "ssm_heads", "ssm_state", None),
            init="zeros_f32",
        ),
    }


def ssm_decode(
    params: dict, x: jax.Array, cache: dict, cfg
) -> tuple[jax.Array, dict]:
    """One decode step. x: (B, 1, d_model)."""
    bsz = x.shape[0]
    d_inner, n_heads, n, p = _dims(cfg)
    cdt = x.dtype

    zxbcdt = jnp.einsum(
        "btd,de->bte", x, params["in_proj"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    z, xs, b, c, dt_raw = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xs, b, c], axis=-1).astype(
        cache["conv"].dtype
    )  # (B, 1, conv_dim)
    win = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, K, conv)
    w = params["conv_w"]
    conv = jnp.sum(
        win.astype(cdt) * w[None].astype(cdt), axis=1, keepdims=True
    )
    xbc = jax.nn.silu(conv + params["conv_b"].astype(cdt)).astype(cdt)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )[:, 0]  # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(a * dt)  # (B, H)

    xh = xs.reshape(bsz, 1, n_heads, p)[:, 0].astype(jnp.float32)  # (B,H,P)
    bv = b[:, 0].astype(jnp.float32)  # (B, N)
    cv = c[:, 0].astype(jnp.float32)
    xdt = xh * dt[..., None]
    h = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", bv, xdt
    )
    y = jnp.einsum("bn,bhnp->bhp", cv, h)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(cdt)
    y = _gated_norm(y, z, params["norm"])
    out = jnp.einsum(
        "bti,id->btd", y, params["out_proj"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    return out, {"conv": win[:, 1:], "state": h}
