"""Parameter specs, initialization, counting, and logical-axis sharding.

Every parameter is declared once as a :class:`ParamSpec` carrying its shape
and *logical* axis names ("embed", "heads", "mlp", "vocab", ...).  A rule
table maps logical names to mesh axes (MaxText-style), with automatic
fallback to replication when a dimension does not divide the mesh axis —
that keeps one rule table valid across all ten architectures (e.g. 8 KV
heads on a 16-way model axis simply replicate).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = [
    "ParamSpec",
    "LogicalRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "spec_to_sharding",
    "tree_shardings",
    "init_params",
    "abstract_params",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


# Logical-axis -> mesh-axis rule tables.  "fsdp" style: the embed/d_model
# axis shards over the data axis for parameters (ZeRO-3), batch shards over
# (pod, data), tensor axes shard over model.
LogicalRules = dict[str, Any]

TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # FSDP: row-shard every d_model axis
    "embed_out": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    # Experts shard over "model" when the count divides it (deepseek: 64);
    # otherwise (grok: 8 experts on a 16-way axis) the expert axis
    # replicates and the expert hidden dim takes the model axis instead —
    # without this fallback the whole expert FFN compute replicates 16x
    # (measured on grok-1 train_4k; §Perf iteration 2).
    "experts": "model",
    "expert_mlp": "model",
    "shared_mlp": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "rwkv_inner": "model",
    "layers": None,           # scan axis — never sharded
    "cache_seq": None,
    "frame": None,
}

# Serving: no optimizer state to shard away; keep weights tensor-parallel
# and shard the KV cache sequence over the data axis (context parallelism)
# when the batch cannot fill it.
SERVE_RULES: LogicalRules = dict(
    TRAIN_RULES,
    embed=None,
    embed_out=None,
    # Context parallelism: the KV-cache sequence shards over every mesh
    # axis the batch dim leaves free (GQA kv-head counts rarely divide the
    # model axis).  Without this, command-r decode_32k holds 40 GiB/device
    # of cache (vs 2.5 sharded) and the memory term is ~16x off roofline
    # (§Perf iteration 7).  Decode attention over the seq-sharded cache is
    # the distributed online-LSE combine — the paper's Eq.-5 trick as a
    # collective.
    cache_seq=("model", "data"),
)

# Compute-time layout for FSDP-stored params: the embed/d_model axis is
# gathered (data-axis shard -> replicated) right before use, layer by layer,
# so every dot keeps its batch dim sharded on "data".  Without the explicit
# gather the SPMD partitioner resolves the batch-vs-contraction conflict by
# replicating the token dim — measured 2.8x per-layer FLOPs (§Perf iter 1).
GATHER_RULES: LogicalRules = dict(TRAIN_RULES, embed=None, embed_out=None)


def gather_for_compute(params: Any, specs: Any, compute_dtype=None) -> Any:
    """with_sharding_constraint params to their compute-time (gathered)
    layout.  No-op unless tracing under a concrete mesh (jax.set_mesh).

    ``compute_dtype``: cast *before* the gather so the FSDP all-gather
    moves 16-bit (or 8-bit) bytes instead of fp32 master weights — halves
    the dominant collective term of the train cells (§Perf iteration 3).
    Only float params narrower than fp32 benefit; int/recurrent leaves pass
    through.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return params

    def g(p, s: ParamSpec):
        if (
            compute_dtype is not None
            and jnp.issubdtype(p.dtype, jnp.floating)
            and jnp.dtype(compute_dtype).itemsize < jnp.dtype(p.dtype).itemsize
        ):
            p = p.astype(compute_dtype)
        spec = logical_to_spec(mesh, p.shape, s.logical, GATHER_RULES)
        return jax.lax.with_sharding_constraint(p, spec)

    return jax.tree.map(
        g, params, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def _mesh_axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(_mesh_axis_size(mesh, a) for a in axis)
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def logical_to_spec(
    mesh, shape: tuple[int, ...], logical: tuple[str | None, ...],
    rules: LogicalRules,
) -> P:
    """Logical axes -> PartitionSpec.

    Degrades gracefully: a rule naming a tuple of mesh axes uses the
    *subset* of axes not already consumed by an earlier dim of the same
    tensor (e.g. cache_seq -> ("model","data") keeps "model" when the batch
    dim took "data"); any dim that cannot divide its remaining axes
    replicates.
    """
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        flat = axis if isinstance(axis, tuple) else (axis,)
        flat = tuple(
            a for a in flat
            if a is not None and a in mesh.axis_names and a not in used
        )
        # largest prefix of the remaining axes whose product divides dim
        chosen: tuple[str, ...] = ()
        for i in range(len(flat), 0, -1):
            size = math.prod(mesh.shape[a] for a in flat[:i])
            if size > 1 and dim % size == 0:
                chosen = flat[:i]
                break
        if not chosen:
            out.append(None)
        else:
            out.append(chosen if len(chosen) > 1 else chosen[0])
            used.update(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_to_sharding(mesh, spec: ParamSpec, rules: LogicalRules):
    return NamedSharding(
        mesh, logical_to_spec(mesh, spec.shape, spec.logical, rules)
    )


def tree_shardings(mesh, specs: Any, rules: LogicalRules):
    """Pytree of ParamSpec -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda s: spec_to_sharding(mesh, s, rules),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _init_one(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros_f32":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(
        dtype
    )


def init_params(key, specs: Any, dtype) -> Any:
    """Initialize a pytree of parameters from a pytree of ParamSpec."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    )


def abstract_params(specs: Any, dtype) -> Any:
    """ShapeDtypeStruct stand-ins (for dry-run lowering, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count from the spec tree (exact)."""
    from repro.models.model import param_specs

    specs = param_specs(cfg)
    total = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0]:
        n = math.prod(spec.shape)
        if active_only and cfg.is_moe:
            keys = "/".join(str(p) for p in path)
            if "routed" in keys:
                # only top-k of the routed experts touch each token
                n = n * cfg.experts_per_token // cfg.num_experts
        total += n
    return total
