"""Block definitions and scanned layer stacks.

One block "kind" per architecture family:

- ``attn_mlp``  — pre-norm GQA attention + gated MLP (dense, vlm, encoder)
- ``attn_moe``  — pre-norm GQA attention + MoE (grok-1, deepseek)
- ``mamba``     — Mamba2/SSD block (zamba2), with a *shared* attention block
                  interleaved every ``attn_every`` layers by the stack
- ``rwkv``      — RWKV6 time-mix + channel-mix

Stacks scan over layers with stacked parameters (the MaxText pattern): one
traced block body regardless of depth, which keeps 512-device HLO compile
times flat in ``num_layers``.  Non-uniform patterns (gemma3's 5 local : 1
global) scan over *superblocks* — parameter leaves shaped (L/6, 6, ...) with
the 6-layer pattern unrolled inside the body — preserving the exact
interleaving without breaking the scan.

Rematerialization wraps the block body (``jax.checkpoint``), policy set by
``cfg.remat``: "full" (nothing saveable), "dots" (matmul outputs saveable),
"none".
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, moe, rwkv, ssm
from repro.models.layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec


def _unroll(cfg):
    return True if cfg.unroll_scans else 1

__all__ = [
    "block_spec",
    "stack_specs",
    "stack_apply",
    "stack_decode",
    "stack_cache_specs",
    "init_stack_cache",
]


# ---------------------------------------------------------------------------
# Single-block spec/apply
# ---------------------------------------------------------------------------


def block_spec(cfg, kind: str) -> dict:
    d = cfg.d_model
    if kind == "attn_mlp":
        return {
            "ln1": rmsnorm_spec(d),
            "attn": attention.attn_spec(cfg),
            "ln2": rmsnorm_spec(d),
            "mlp": mlp_spec(d, cfg.d_ff, bias=cfg.use_bias),
        }
    if kind == "attn_moe":
        return {
            "ln1": rmsnorm_spec(d),
            "attn": attention.attn_spec(cfg),
            "ln2": rmsnorm_spec(d),
            "moe": moe.moe_spec(cfg),
        }
    if kind == "mamba":
        return {"ln": rmsnorm_spec(d), "ssm": ssm.ssm_spec(cfg)}
    if kind == "rwkv":
        return {
            "ln1": rmsnorm_spec(d),
            "time": rwkv.rwkv_time_spec(cfg),
            "ln2": rmsnorm_spec(d),
            "chan": rwkv.rwkv_channel_spec(cfg),
        }
    raise ValueError(kind)


def _block_fwd(params, x, cfg, policy, *, kind: str, window: int):
    """Full-sequence block. Returns (x, aux)."""
    from repro.models.params import gather_for_compute

    # FSDP: cast to the compute dtype, then gather the embed-axis shards of
    # this layer's weights (explicit ZeRO-3 all-gather of 16-bit bytes; see
    # params.GATHER_RULES and gather_for_compute).
    params = gather_for_compute(
        params, block_spec(cfg, kind), policy.compute_dtype
    )
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_moe"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + attention.attention(
            params["attn"], h, cfg, window=window,
            accum_dtype=policy.accum_dtype,
        )
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if kind == "attn_mlp":
            x = x + mlp(params["mlp"], h, cfg.act)
        else:
            y, aux = moe.moe(params["moe"], h, cfg)
            x = x + y
    elif kind == "mamba":
        h = rmsnorm(params["ln"], x, cfg.norm_eps)
        x = x + ssm.ssm_forward(params["ssm"], h, cfg)
    elif kind == "rwkv":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + rwkv.rwkv_time_forward(params["time"], h, cfg)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + rwkv.rwkv_channel_forward(params["chan"], h, cfg)
    else:
        raise ValueError(kind)
    return x, aux


def _block_decode(params, x, cache, pos, cfg, policy, *, kind: str, window: int):
    """One-token block step. Returns (x, new_cache)."""
    if kind in ("attn_mlp", "attn_moe"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, kv = attention.decode_attn(
            params["attn"], h, attention.KVCache(**cache["kv"]), pos, cfg,
            window=window, accum_dtype=policy.accum_dtype,
        )
        x = x + y
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if kind == "attn_mlp":
            x = x + mlp(params["mlp"], h, cfg.act)
        else:
            y, _ = moe.moe(params["moe"], h, cfg)
            x = x + y
        return x, {"kv": {"k": kv.k, "v": kv.v}}
    if kind == "mamba":
        h = rmsnorm(params["ln"], x, cfg.norm_eps)
        y, new = ssm.ssm_decode(params["ssm"], h, cache, cfg)
        return x + y, new
    if kind == "rwkv":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, cache = rwkv.rwkv_time_decode(params["time"], h, cache, cfg)
        x = x + y
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, cache = rwkv.rwkv_channel_decode(params["chan"], h, cache, cfg)
        return x + y, cache
    raise ValueError(kind)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------
# Uniform stacks: {"layers": leaf-stacked (L, ...)}.
# gemma3:   {"supers": (L//G, G, ...), "tail": (L%G, ...)} pattern local^5,global
# zamba2:   {"groups": (L/E, E, ...) mamba, "shared_attn": {...}} one shared block
# ---------------------------------------------------------------------------


def _stacked(spec: dict, n: int):
    from repro.models.params import ParamSpec

    def add_axis(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (n,) + s.shape, ("layers",) + s.logical, init=s.init, scale=s.scale
        )

    return jax.tree.map(
        add_axis, spec, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def _kind(cfg) -> str:
    if cfg.is_rwkv:
        return "rwkv"
    if cfg.ssm_state and cfg.attn_every:
        return "mamba"
    if cfg.is_moe:
        return "attn_moe"
    return "attn_mlp"


def stack_specs(cfg) -> dict:
    kind = _kind(cfg)
    if cfg.global_every:  # gemma3-style local:global pattern
        g = cfg.global_every
        n_super, n_tail = divmod(cfg.num_layers, g)
        spec = {
            "supers": _stacked(_stacked(block_spec(cfg, kind), g), n_super)
        }
        if n_tail:
            spec["tail"] = _stacked(block_spec(cfg, kind), n_tail)
        return spec
    if kind == "mamba":  # zamba2: groups + one shared attention block
        e = cfg.attn_every
        n_groups = cfg.num_layers // e
        return {
            "groups": _stacked(_stacked(block_spec(cfg, "mamba"), e), n_groups),
            "shared_attn": block_spec(cfg, "attn_mlp"),
        }
    return {"layers": _stacked(block_spec(cfg, kind), cfg.num_layers)}


# ---------------------------------------------------------------------------
# Forward (train/prefill)
# ---------------------------------------------------------------------------


def stack_apply(params: dict, x: jax.Array, cfg, policy) -> tuple[jax.Array, jax.Array]:
    """Run the full stack over (B, S, D). Returns (x, moe_aux_sum)."""
    kind = _kind(cfg)

    if cfg.global_every:
        g = cfg.global_every

        def super_body(carry, layer_params):
            x, aux = carry
            for i in range(g):
                p_i = jax.tree.map(lambda p: p[i], layer_params)
                win = cfg.window if (i + 1) % g else 0
                x, a = _block_fwd(
                    p_i, x, cfg, policy, kind=kind, window=win
                )
                aux = aux + a
            return (x, aux), None

        body = _remat(super_body, cfg.remat)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["supers"],
            unroll=_unroll(cfg),
        )
        if "tail" in params:

            def tail_body(carry, p):
                x, aux = carry
                x, a = _block_fwd(
                    p, x, cfg, policy, kind=kind, window=cfg.window
                )
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(
                _remat(tail_body, cfg.remat), (x, aux), params["tail"],
                unroll=_unroll(cfg),
            )
        return x, aux

    if kind == "mamba":
        e = cfg.attn_every
        shared = params["shared_attn"]

        def group_body(carry, group_params):
            x, aux = carry

            def inner(carry2, p):
                x2, = carry2
                x2, _ = _block_fwd(p, x2, cfg, policy, kind="mamba", window=0)
                return (x2,), None

            (x,), _ = jax.lax.scan(
                inner, (x,), group_params, unroll=_unroll(cfg)
            )
            x, _ = _block_fwd(
                shared, x, cfg, policy, kind="attn_mlp", window=0
            )
            return (x, aux), None

        body = _remat(group_body, cfg.remat)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["groups"],
            unroll=_unroll(cfg),
        )
        return x, aux

    def layer_body(carry, p):
        x, aux = carry
        x, a = _block_fwd(p, x, cfg, policy, kind=kind, window=cfg.window)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        _remat(layer_body, cfg.remat),
        (x, jnp.zeros((), jnp.float32)),
        params["layers"],
        unroll=_unroll(cfg),
    )
    return x, aux


# ---------------------------------------------------------------------------
# Caches + decode
# ---------------------------------------------------------------------------


def _layer_cache_spec(cfg, kind: str, batch: int, s_max: int, window: int):
    if kind in ("attn_mlp", "attn_moe"):
        alloc = min(window, s_max) if window else s_max
        return {"kv": attention.kv_cache_spec(cfg, batch, alloc)}
    if kind == "mamba":
        return ssm.ssm_cache_spec(cfg, batch)
    if kind == "rwkv":
        return rwkv.rwkv_cache_spec(cfg, batch)
    raise ValueError(kind)


def stack_cache_specs(cfg, batch: int, s_max: int) -> dict:
    kind = _kind(cfg)
    if cfg.global_every:
        g = cfg.global_every
        n_super, n_tail = divmod(cfg.num_layers, g)
        # Per-superblock: g-1 ring-buffer local layers + 1 full-length global.
        local = _layer_cache_spec(cfg, kind, batch, s_max, cfg.window)
        glob = _layer_cache_spec(cfg, kind, batch, s_max, 0)
        spec = {
            "supers_local": _stacked(_stacked(local, g - 1), n_super),
            "supers_global": _stacked(glob, n_super),
        }
        if n_tail:
            spec["tail"] = _stacked(local, n_tail)
        return spec
    if kind == "mamba":
        e = cfg.attn_every
        n_groups = cfg.num_layers // e
        return {
            "groups": _stacked(
                _stacked(_layer_cache_spec(cfg, "mamba", batch, s_max, 0), e),
                n_groups,
            ),
            "shared_attn": _stacked(
                _layer_cache_spec(cfg, "attn_mlp", batch, s_max, 0), n_groups
            ),
        }
    return {
        "layers": _stacked(
            _layer_cache_spec(cfg, kind, batch, s_max, cfg.window),
            cfg.num_layers,
        )
    }


def init_stack_cache(cfg, batch: int, s_max: int, dtype) -> dict:
    from repro.models.params import ParamSpec

    def make(s: ParamSpec):
        # recurrent states stay fp32 (marked zeros_f32); the rest cache dtype
        dt = jnp.float32 if s.init == "zeros_f32" else dtype
        return jnp.zeros(s.shape, dt)

    specs = stack_cache_specs(cfg, batch, s_max)
    return jax.tree.map(
        make, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def stack_decode(
    params: dict, x: jax.Array, cache: dict, pos: jax.Array, cfg, policy
) -> tuple[jax.Array, dict]:
    """One-token step through the whole stack. x: (B, 1, D)."""
    kind = _kind(cfg)

    if cfg.global_every:
        g = cfg.global_every

        def super_body(x, layer):
            p_all, c_loc, c_glob = layer
            new_loc = []
            for i in range(g - 1):
                p_i = jax.tree.map(lambda p: p[i], p_all)
                c_i = jax.tree.map(lambda c: c[i], c_loc)
                x, c_i = _block_decode(
                    p_i, x, c_i, pos, cfg, policy, kind=kind, window=cfg.window
                )
                new_loc.append(c_i)
            p_g = jax.tree.map(lambda p: p[g - 1], p_all)
            x, c_glob = _block_decode(
                p_g, x, c_glob, pos, cfg, policy, kind=kind, window=0
            )
            new_loc = jax.tree.map(lambda *cs: jnp.stack(cs), *new_loc)
            return x, (new_loc, c_glob)

        x, (new_loc, new_glob) = jax.lax.scan(
            super_body, x, (params["supers"], cache["supers_local"],
                            cache["supers_global"]),
            unroll=_unroll(cfg),
        )
        new_cache = {"supers_local": new_loc, "supers_global": new_glob}
        if "tail" in params:

            def tail_body(x, layer):
                p, c = layer
                x, c = _block_decode(
                    p, x, c, pos, cfg, policy, kind=kind, window=cfg.window
                )
                return x, c

            x, new_tail = jax.lax.scan(
                tail_body, x, (params["tail"], cache["tail"]),
                unroll=_unroll(cfg),
            )
            new_cache["tail"] = new_tail
        return x, new_cache

    if kind == "mamba":
        shared = params["shared_attn"]

        def group_body(x, layer):
            gp, gc, ac = layer

            def inner(x2, pc):
                p, c = pc
                x2, c = _block_decode(
                    p, x2, c, pos, cfg, policy, kind="mamba", window=0
                )
                return x2, c

            x, new_gc = jax.lax.scan(
                inner, x, (gp, gc), unroll=_unroll(cfg)
            )
            x, new_ac = _block_decode(
                shared, x, ac, pos, cfg, policy, kind="attn_mlp", window=0
            )
            return x, (new_gc, new_ac)

        x, (new_groups, new_attn) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["groups"], cache["shared_attn"]),
            unroll=_unroll(cfg),
        )
        return x, {"groups": new_groups, "shared_attn": new_attn}

    def layer_body(x, layer):
        p, c = layer
        x, c = _block_decode(
            p, x, c, pos, cfg, policy, kind=kind, window=cfg.window
        )
        return x, c

    x, new_cache = jax.lax.scan(
        layer_body, x, (params["layers"], cache["layers"]),
        unroll=_unroll(cfg),
    )
    return x, {"layers": new_cache}
