"""GQA attention: train/prefill (full or sliding-window) + cached decode.

Numerics follow the paper's discipline: attention softmax is the Eq.-5
log-sum-exp pattern — scores reduce in fp32 (``stable_softmax`` with
``accum_dtype``) while activations stay 16-bit.  Decode against a
sequence-sharded KV cache uses the *distributed* online-LSE combine
(``stability.lse_combine``) in the shard_map path (`decode_attn_sharded`),
the same primitive the distributed particle filter uses for its weights.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import stability
from repro.models.layers import rope
from repro.models.params import ParamSpec

__all__ = [
    "attn_spec",
    "attention",
    "decode_attn",
    "init_kv_cache",
    "kv_cache_spec",
]


def attn_spec(cfg) -> dict:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    spec = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed_out")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return spec


def _qkv(params, x, cfg, positions):
    cdt = x.dtype
    q = jnp.einsum(
        "bsd,dhk->bshk", x, params["wq"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    k = jnp.einsum(
        "bsd,dhk->bshk", x, params["wk"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    v = jnp.einsum(
        "bsd,dhk->bshk", x, params["wv"].astype(cdt),
        preferred_element_type=jnp.float32,
    ).astype(cdt)
    if cfg.qk_norm:
        q = _head_rms(q, params["q_norm"])
        k = _head_rms(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pin_cache(kv: jax.Array, cfg) -> jax.Array:
    """Constrain a (b, s, kh, hd) cache tensor to its storage sharding."""
    if not getattr(cfg, "pin_decode_cache", False):
        return kv
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return kv
    from repro.models.params import SERVE_RULES, logical_to_spec

    spec = logical_to_spec(
        mesh, kv.shape, ("batch", "cache_seq", "kv_heads", "head_dim"),
        SERVE_RULES,
    )
    return jax.lax.with_sharding_constraint(kv, spec)


def _head_rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def _mask(sq: int, sk: int, *, causal: bool, window: int, offset: int = 0):
    """(sq, sk) boolean mask. offset: absolute position of query row 0."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def _sdpa(q, k, v, mask, accum_dtype, *, expand_kv: bool = True):
    """q: (b, sq, h, hd); k/v: (b, sk, kh, hd) GQA.

    ``expand_kv=True`` (default) repeats K/V up to the query head count so
    the score/value einsums carry a single head axis that shards cleanly
    over the model mesh axis.  The grouped form ("bskgh,btkh->bkgst") splits
    the sharded head axis into (kv, group) — XLA cannot map a 16-way mesh
    axis onto the kv=8 sub-dimension and silently *replicates* the whole
    attention computation (measured: 2.9x per-layer fwd FLOPs on the 16x16
    mesh; §Perf iteration 1).  The repeat costs one (b, sk, h, hd) bf16
    buffer — the same size as q — and vanishes on kv==h archs.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    if expand_kv and g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        scores = jnp.einsum(
            "bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32
        ) * (hd**-0.5)
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        probs = stability.stable_softmax(
            scores, axis=-1, accum_dtype=accum_dtype
        )
        return jnp.einsum(
            "bhst,bthd->bshd", probs.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        ).astype(q.dtype)
    qg = q.reshape(b, sq, kh, g, hd)
    scores = jnp.einsum(
        "bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = stability.stable_softmax(scores, axis=-1, accum_dtype=accum_dtype)
    out = jnp.einsum(
        "bkgst,btkh->bskgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    return out.reshape(b, sq, h, hd)


def attention(
    params: dict,
    x: jax.Array,
    cfg,
    *,
    window: int = 0,
    positions: jax.Array | None = None,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Full (train/prefill) attention. x: (b, s, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(params, x, cfg, positions)
    mask = _mask(s, s, causal=cfg.causal, window=window)
    out = _sdpa(q, k, v, mask, accum_dtype)
    return jnp.einsum(
        "bshk,hkd->bsd", out, params["wo"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


class KVCache(NamedTuple):
    k: jax.Array  # (b, s_max, kv_heads, hd)
    v: jax.Array


def kv_cache_spec(cfg, batch: int, s_max: int) -> dict:
    """ParamSpec-style declaration of the cache (for shardings)."""
    shape = (batch, s_max, cfg.num_kv_heads, cfg.hd)
    logical = ("batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec(shape, logical, init="zeros"),
        "v": ParamSpec(shape, logical, init="zeros"),
    }


def init_kv_cache(cfg, batch: int, s_max: int, dtype) -> KVCache:
    shape = (batch, s_max, cfg.num_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_attn(
    params: dict,
    x: jax.Array,
    cache: KVCache,
    pos: jax.Array,
    cfg,
    *,
    window: int = 0,
    accum_dtype=jnp.float32,
) -> tuple[jax.Array, KVCache]:
    """One decode step. x: (b, 1, d); pos: scalar int32 (synchronized batch).

    Writes this step's K/V then attends to the valid cache region.  The
    masked softmax is the stable-LSE form; for a sequence-sharded cache XLA
    partitions the reduction into per-shard partial LSEs combined over the
    mesh — structurally identical to ``stability.lse_combine``.

    Sliding-window layers use a **ring buffer**: the cache is allocated at
    ``window`` (not seq_len) entries and this step writes slot
    ``pos % window``.  Keys are stored *post-RoPE* (rotated at their
    absolute position when written), so ring slots need no position
    bookkeeping — only a validity mask while the buffer fills.  This is
    what makes 500k-token decode memory-feasible for 5:1 local:global
    stacks: local layers hold window·kv·hd instead of 500k·kv·hd.
    """
    q, k_new, v_new = _qkv(params, x, cfg, pos[None, None])
    s_max = cache.k.shape[1]
    ring = bool(window) and s_max == window
    slot = jax.lax.rem(pos, jnp.int32(window)) if ring else pos
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0)
    )
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0)
    )
    # Pin the updated cache to its storage layout (batch-sharded, heads/seq
    # replicated).  Without this the auto-partitioner shards the kv-head
    # axis over part of the model axis mid-graph and then all-gathers it
    # back *in fp32* for the attention — measured at ~0.27 GB/layer/step on
    # command-r decode_32k (§Perf).
    k, v = _pin_cache(k, cfg), _pin_cache(v, cfg)

    kpos = jnp.arange(s_max)
    if ring:
        valid = kpos < jnp.minimum(pos + 1, window)
    else:
        valid = kpos <= pos
        if window:
            valid &= kpos > pos - window
    mask = valid[None, :]  # (1, s_max) -> query row broadcast

    out = _sdpa(
        q, k.astype(q.dtype), v.astype(q.dtype), mask, accum_dtype,
        expand_kv=cfg.decode_expand_kv,
    )
    y = jnp.einsum(
        "bshk,hkd->bsd", out, params["wo"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    return y, KVCache(k, v)
