"""RWKV6 ("Finch") — data-dependent-decay linear attention, chunked.

Recurrence per head (state S: key_dim x value_dim):

    y_t = r_t · S_{t-1}  +  (r_t · (u ⊙ k_t)) v_t          (bonus term)
    S_t = diag(w_t) S_{t-1}  +  k_t ⊗ v_t

with w_t = exp(-exp(w0 + tanh(x̃_t A) B)) a *data-dependent* per-channel
decay (the low-rank "Finch" parameterization).  Training uses the chunked
form: within a chunk, decays telescope through cumulative log-sums (fp32 —
the stability discipline again: 16-bit cumprods of near-1 decays are
exactly the paper's vanishing-weight failure), across chunks the state is
carried by ``lax.scan``.

Decode carries (token-shift x_prev, S) per layer — O(1) state, no KV cache:
this is the arch that showcases the 500k cell.

Simplifications vs. the reference implementation (documented in DESIGN.md):
static token-shift mixing coefficients (RWKV5 style) instead of the
per-token dynamic mix, and a single LayerNorm-free gated output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

__all__ = [
    "rwkv_time_spec",
    "rwkv_channel_spec",
    "rwkv_time_forward",
    "rwkv_channel_forward",
    "rwkv_time_decode",
    "rwkv_channel_decode",
    "init_rwkv_cache",
    "rwkv_cache_spec",
    "HEAD_DIM",
]

HEAD_DIM = 64
LORA_RANK = 64


def _heads(cfg):
    return cfg.d_model // HEAD_DIM


def rwkv_time_spec(cfg) -> dict:
    d = cfg.d_model
    return {
        "mix_r": ParamSpec((d,), ("embed",), init="zeros"),
        "mix_k": ParamSpec((d,), ("embed",), init="zeros"),
        "mix_v": ParamSpec((d,), ("embed",), init="zeros"),
        "mix_w": ParamSpec((d,), ("embed",), init="zeros"),
        "mix_g": ParamSpec((d,), ("embed",), init="zeros"),
        "wr": ParamSpec((d, d), ("embed", "rwkv_inner")),
        "wk": ParamSpec((d, d), ("embed", "rwkv_inner")),
        "wv": ParamSpec((d, d), ("embed", "rwkv_inner")),
        "wg": ParamSpec((d, d), ("embed", "rwkv_inner")),
        "wo": ParamSpec((d, d), ("rwkv_inner", "embed_out")),
        "w0": ParamSpec((d,), ("embed",), init="zeros"),
        "w_a": ParamSpec((d, LORA_RANK), ("embed", None), scale=0.1),
        "w_b": ParamSpec((LORA_RANK, d), (None, "embed"), scale=0.1),
        "u": ParamSpec((d,), ("embed",), init="zeros"),
        "ln_x": ParamSpec((d,), ("embed",), init="ones"),
    }


def rwkv_channel_spec(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix_k": ParamSpec((d,), ("embed",), init="zeros"),
        "mix_r": ParamSpec((d,), ("embed",), init="zeros"),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed_out")),
        "wr": ParamSpec((d, d), ("embed", "rwkv_inner")),
    }


def _shift(x, x_prev):
    """Token shift: prepend x_prev, drop last. x: (B,T,D); x_prev: (B,1,D)."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _proj(w, x):
    return jnp.einsum(
        "btd,de->bte", x, w.astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def _decay(params, xw):
    """Data-dependent log-decay (negative), fp32. xw: (B,T,D)."""
    lora = jnp.einsum(
        "btd,dr->btr", xw.astype(jnp.float32), params["w_a"].astype(jnp.float32)
    )
    lw = params["w0"].astype(jnp.float32) + jnp.einsum(
        "btr,rd->btd", jnp.tanh(lora), params["w_b"].astype(jnp.float32)
    )
    return -jnp.exp(lw)  # log w_t  (w_t = exp(-exp(...)) in (0,1))


def _group_norm(x, scale, eps=1e-5):
    """Per-head group norm on (B, T, H, P)."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    b, t, h, p = x.shape
    return (y.reshape(b, t, h * p) * scale.astype(jnp.float32)).astype(x.dtype)


def rwkv_time_forward(
    params: dict, x: jax.Array, cfg, *, chunk: int | None = None
) -> jax.Array:
    """Full-sequence chunked WKV6. x: (B, T, D)."""
    chunk = chunk or getattr(cfg, "rwkv_chunk", 16)
    bsz, t, d = x.shape
    h = _heads(cfg)
    p = HEAD_DIM
    cdt = x.dtype
    xs = _shift(x, jnp.zeros((bsz, 1, d), cdt))

    r = _proj(params["wr"], _mix(x, xs, params["mix_r"]))
    k = _proj(params["wk"], _mix(x, xs, params["mix_k"]))
    v = _proj(params["wv"], _mix(x, xs, params["mix_v"]))
    g = _proj(params["wg"], _mix(x, xs, params["mix_g"]))
    lw = _decay(params, _mix(x, xs, params["mix_w"]))  # (B,T,D) fp32, <0

    def hs(z):  # (B,T,D) -> (B,T,H,P)
        return z.reshape(bsz, t, h, p)

    rh, kh, vh = hs(r).astype(jnp.float32), hs(k).astype(jnp.float32), hs(
        v
    ).astype(jnp.float32)
    lwh = hs(lw)
    uh = params["u"].astype(jnp.float32).reshape(h, p)

    import math

    chunk = math.gcd(t, chunk)
    nchunks = t // chunk

    def rc(z):  # (B, T, H, P) -> (C, B, Q, H, P)
        return jnp.moveaxis(
            z.reshape(bsz, nchunks, chunk, h, p), 1, 0
        )

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)  # strictly lower

    def chunk_step(S, inp):
        rq, kq, vq, lq = inp  # (B, Q, H, P)
        cum = jnp.cumsum(lq, axis=1)  # (B,Q,H,P) inclusive, <= 0
        cum_prev = cum - lq
        # Intra-chunk: the naive r̃=r·exp(cum), k̃=k·exp(-cum) factorization
        # overflows fp32 for fast-forgetting channels (exp(+|cum|) -> inf);
        # instead build the exact per-channel decay tensor, whose exponents
        # cum_{i-1}-cum_j are <= 0 for every kept (j < i) entry — nothing
        # can overflow.  Memory O(Q^2·P) per head, why the chunk is small.
        dec = jnp.exp(
            jnp.where(
                mask[None, :, :, None, None],
                cum_prev[:, :, None] - cum[:, None, :],
                -jnp.inf,
            )
        )  # (B, Qi, Qj, H, P)
        if getattr(cfg, "rwkv_intra_bf16", False):
            # decays are in [0,1] — bf16 storage halves the dominant HBM
            # traffic of the chunked form (§Perf, rwkv prefill cell)
            # analysis: allow(dtype-literal): config-gated (rwkv_intra_bf16)
            # storage choice, documented above — not a policy bypass
            dec = dec.astype(jnp.bfloat16)
        att = jnp.einsum("bihp,bjhp,bijhp->bhij", rq, kq, dec)
        y = jnp.einsum("bhij,bjhp->bihp", att, vq)
        # bonus (current token)
        bonus = jnp.einsum("bihp,bihp->bih", rq, uh[None, None] * kq)
        y = y + bonus[..., None] * vq
        # carried state: r_i · diag(exp(cum_{i-1})) S   (exponents <= 0)
        r_t = rq * jnp.exp(cum_prev)
        y = y + jnp.einsum("bihk,bhkp->bihp", r_t, S)
        # state update: S' = diag(exp(cum_Q)) S + sum_j exp(cum_Q - cum_j) k_j v_j
        total = cum[:, -1]  # (B,H,P), <= 0
        k_rem = kq * jnp.exp(total[:, None] - cum)  # exponents <= 0
        S_new = S * jnp.exp(total)[..., None] + jnp.einsum(
            "bjhk,bjhp->bhkp", k_rem, vq
        )
        if getattr(cfg, "rwkv_intra_bf16", False):
            y = y.astype(cdt)  # chunk outputs stored compute-width
        return S_new, y

    S0 = jnp.zeros((bsz, h, p, p), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step, S0, (rc(rh), rc(kh), rc(vh), rc(lwh)),
        unroll=True if cfg.unroll_scans else 1,
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, h, p)

    y = _group_norm(y, params["ln_x"])
    y = (y.astype(jnp.float32) * jax.nn.silu(g.astype(jnp.float32))).astype(cdt)
    return _proj(params["wo"], y)


def rwkv_channel_forward(params: dict, x: jax.Array, cfg) -> jax.Array:
    bsz, t, d = x.shape
    xs = _shift(x, jnp.zeros((bsz, 1, d), x.dtype))
    xk = _mix(x, xs, params["mix_k"])
    xr = _mix(x, xs, params["mix_r"])
    k = jnp.square(jax.nn.relu(_proj(params["wk"], xk)))
    kv = _proj(params["wv"], k)
    return jax.nn.sigmoid(_proj(params["wr"], xr)) * kv


def init_rwkv_cache(cfg, batch: int, dtype) -> dict:
    h, p, d = _heads(cfg), HEAD_DIM, cfg.d_model
    return {
        "state": jnp.zeros((batch, h, p, p), jnp.float32),
        "x_time": jnp.zeros((batch, 1, d), dtype),
        "x_chan": jnp.zeros((batch, 1, d), dtype),
    }


def rwkv_cache_spec(cfg, batch: int) -> dict:
    h, p, d = _heads(cfg), HEAD_DIM, cfg.d_model
    return {
        "state": ParamSpec(
            (batch, h, p, p), ("batch", "heads", None, None), init="zeros_f32"
        ),
        "x_time": ParamSpec((batch, 1, d), ("batch", None, "embed"), init="zeros"),
        "x_chan": ParamSpec((batch, 1, d), ("batch", None, "embed"), init="zeros"),
    }


def rwkv_time_decode(
    params: dict, x: jax.Array, cache: dict, cfg
) -> tuple[jax.Array, dict]:
    """One step. x: (B, 1, D)."""
    bsz, _, d = x.shape
    h, p = _heads(cfg), HEAD_DIM
    cdt = x.dtype
    xs = cache["x_time"].astype(cdt)

    r = _proj(params["wr"], _mix(x, xs, params["mix_r"]))
    k = _proj(params["wk"], _mix(x, xs, params["mix_k"]))
    v = _proj(params["wv"], _mix(x, xs, params["mix_v"]))
    g = _proj(params["wg"], _mix(x, xs, params["mix_g"]))
    lw = _decay(params, _mix(x, xs, params["mix_w"]))  # (B,1,D)

    rh = r.reshape(bsz, h, p).astype(jnp.float32)
    kh = k.reshape(bsz, h, p).astype(jnp.float32)
    vh = v.reshape(bsz, h, p).astype(jnp.float32)
    wh = jnp.exp(lw.reshape(bsz, h, p))  # decay in (0,1)
    uh = params["u"].astype(jnp.float32).reshape(h, p)

    S = cache["state"]
    kv = jnp.einsum("bhk,bhp->bhkp", kh, vh)
    y = jnp.einsum("bhk,bhkp->bhp", rh, S + uh[None, ..., None] * kv)
    S_new = S * wh[..., None] + kv

    y = y.reshape(bsz, 1, h, p)
    y = _group_norm(y, params["ln_x"])
    y = (y.astype(jnp.float32) * jax.nn.silu(g.astype(jnp.float32))).astype(cdt)
    out = _proj(params["wo"], y)
    return out, dict(
        cache, state=S_new, x_time=x.astype(cache["x_time"].dtype)
    )


def rwkv_channel_decode(
    params: dict, x: jax.Array, cache: dict, cfg
) -> tuple[jax.Array, dict]:
    xs = cache["x_chan"].astype(x.dtype)
    xk = _mix(x, xs, params["mix_k"])
    xr = _mix(x, xs, params["mix_r"])
    k = jnp.square(jax.nn.relu(_proj(params["wk"], xk)))
    kv = _proj(params["wv"], k)
    out = jax.nn.sigmoid(_proj(params["wr"], xr)) * kv
    return out, dict(cache, x_chan=x.astype(cache["x_chan"].dtype))
