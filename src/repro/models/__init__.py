"""LM substrate: layers, attention, MoE, SSM, RWKV, stacks, and the LM API."""
