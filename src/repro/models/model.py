"""The language model: specs, forward, loss, prefill, decode.

Frontends (per the assignment, modality frontends are STUBS):
- ``token``  — token ids (B, S) through the embedding table.
- ``frames`` — precomputed audio frame embeddings (B, S, frame_dim) through a
  learned projector (hubert; encoder-only, masked-prediction loss).
- ``vlm``    — precomputed patch embeddings (B, S_img, d_model) through a
  learned projector, concatenated before the token embeddings (internvl2);
  loss on text positions only.

Loss is next-token cross-entropy with the stable log-softmax (max-subtracted,
fp32 reductions — the paper's Eq.-5 pattern at vocab scale) plus a small
z-loss; 16-bit logits feed fp32 reductions without materializing fp32 logits.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.layers import embed_lookup, embed_spec, rmsnorm, rmsnorm_spec
from repro.models.params import ParamSpec
from repro.models.params import init_params as _init

__all__ = [
    "param_specs",
    "init_params",
    "forward",
    "loss_fn",
    "cache_specs",
    "init_cache",
    "prefill",
    "decode_step",
]

Z_LOSS = 1e-4


def param_specs(cfg) -> dict:
    d = cfg.d_model
    spec: dict[str, Any] = {
        "embed": embed_spec(cfg.vocab_size, d),
        "stack": transformer.stack_specs(cfg),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = {
            "w": ParamSpec((d, cfg.vocab_size), ("embed", "vocab"))
        }
    if cfg.frontend == "frames":
        fd = cfg.frame_dim or d
        spec["frontend"] = {
            "proj": ParamSpec((fd, d), ("frame", "embed")),
        }
    elif cfg.frontend == "vlm":
        spec["frontend"] = {"proj": ParamSpec((d, d), (None, "embed"))}
    return spec


def init_params(key, cfg, dtype) -> dict:
    return _init(key, param_specs(cfg), dtype)


def _embed_inputs(params, batch: dict, cfg, dtype) -> jax.Array:
    """batch -> (B, S, D) embeddings, per frontend."""
    if cfg.frontend == "frames":
        x = jnp.einsum(
            "bsf,fd->bsd", batch["frames"].astype(dtype),
            params["frontend"]["proj"].astype(dtype),
        )
        s, d = x.shape[1], x.shape[2]
        # sinusoidal positions: length-agnostic (the real model's conv
        # position encoder is part of the stubbed frontend)
        pos = jnp.arange(s, dtype=jnp.float32)[:, None]
        dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
        ang = pos / jnp.power(10_000.0, 2.0 * dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        return x + pe[None].astype(dtype)
    if cfg.frontend == "vlm":
        img = jnp.einsum(
            "bsd,de->bse", batch["patch_embeds"].astype(dtype),
            params["frontend"]["proj"].astype(dtype),
        )
        txt = embed_lookup(params["embed"], batch["tokens"], dtype)
        return jnp.concatenate([img, txt], axis=1)
    return embed_lookup(params["embed"], batch["tokens"], dtype)


def _logits(params, x, cfg):
    # Logits stay in the compute dtype (a fp32 (B,S,V) buffer would be the
    # single largest tensor at 256k vocab); the loss upcasts inside its
    # reductions, which XLA fuses without materializing fp32 logits.
    if cfg.tie_embeddings:
        out = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        out = jnp.einsum(
            "bsd,dv->bsv", x, params["lm_head"]["w"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
    return out.astype(x.dtype)


def forward(params, batch: dict, cfg, policy) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V) compute dtype, moe_aux)."""
    from repro.models.params import gather_for_compute

    cdt = policy.compute_dtype
    # FSDP: gather the non-stack params (embed table, head, frontend) once,
    # cast to the compute dtype before the gather (16-bit wire bytes).
    specs = param_specs(cfg)
    outer = {k: v for k, v in params.items() if k != "stack"}
    outer = gather_for_compute(outer, {k: specs[k] for k in outer}, cdt)
    params = dict(outer, stack=params["stack"])
    x = _embed_inputs(params, batch, cfg, cdt)
    x, aux = transformer.stack_apply(params["stack"], x, cfg, policy)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), aux


def _stable_xent(logits, labels, mask):
    """Mean masked CE + z-loss, fp32 reductions (Eq.-5 discipline)."""
    logits_f32 = logits.astype(jnp.float32)
    m = jnp.max(logits_f32, axis=-1, keepdims=True)
    shifted = logits_f32 - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + jnp.squeeze(
        jax.lax.stop_gradient(m), -1
    )
    label_logit = jnp.take_along_axis(
        logits_f32, labels[..., None], axis=-1
    )[..., 0]
    nll = lse - label_logit
    zl = Z_LOSS * jnp.square(lse)
    total = jnp.sum((nll + zl) * mask)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return total / denom, total * 0.0 + denom


def loss_fn(params, batch: dict, cfg, policy) -> tuple[jax.Array, dict]:
    """Scalar loss + metrics for one (micro)batch."""
    logits, aux = forward(params, batch, cfg, policy)
    if cfg.is_encoder:
        # HuBERT-style masked prediction: loss on masked positions.
        labels = batch["labels"]
        mask = batch["mask"].astype(jnp.float32)
        ce, _ = _stable_xent(logits, labels, mask)
    elif cfg.frontend == "vlm":
        # next-token loss on the text segment only
        s_img = cfg.vlm_image_seq
        txt_logits = logits[:, s_img:-1]
        labels = batch["tokens"][:, 1:]
        mask = jnp.ones(labels.shape, jnp.float32)
        ce, _ = _stable_xent(txt_logits, labels, mask)
    else:
        labels = batch["tokens"][:, 1:]
        mask = batch.get("mask")
        mask = (
            jnp.ones(labels.shape, jnp.float32)
            if mask is None
            else mask[:, 1:].astype(jnp.float32)
        )
        ce, _ = _stable_xent(logits[:, :-1], labels, mask)
    loss = ce + (0.01 * aux if cfg.is_moe else 0.0)
    metrics = {"ce": ce, "moe_aux": aux}
    return loss * policy.loss_scale, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg, batch: int, s_max: int) -> dict:
    return transformer.stack_cache_specs(cfg, batch, s_max)


def init_cache(cfg, batch: int, s_max: int, dtype) -> dict:
    return transformer.init_stack_cache(cfg, batch, s_max, dtype)


def prefill(params, batch: dict, cfg, policy, s_max: int):
    """Process a full prompt; returns (last-token logits, filled cache).

    Prefill runs the full-sequence path and then *bulk-writes* the cache
    (train-shaped compute, decode-shaped output) — faithful to how serving
    frameworks split prefill/decode.  For simplicity the bulk write is only
    implemented for uniform attention stacks; pattern stacks prefill by
    scanning decode steps (correct, slower — documented).
    """
    cdt = policy.compute_dtype
    logits, _ = forward(params, batch, cfg, policy)
    return logits[:, -1]


def decode_step(params, token, pos, cache, cfg, policy):
    """One token for the whole batch. token: (B,) int32; pos: scalar int32."""
    cdt = policy.compute_dtype
    x = embed_lookup(params["embed"], token[:, None], cdt)
    x, cache = transformer.stack_decode(
        params["stack"], x, cache, pos, cfg, policy
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, x, cfg)
    return logits[:, 0], cache
