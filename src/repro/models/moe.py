"""Mixture-of-experts layer (grok-1 coarse / deepseek fine-grained styles).

Router softmax runs in fp32 (the framework-wide stable-softmax discipline —
in 16-bit routing, logit ties collapse expert diversity).  Dispatch uses
sort-based grouping with a static capacity:

    flatten tokens → top-k experts → argsort by expert id →
    scatter into (E, C, d) expert batches → two-matmul expert FFN →
    gather back with gate-weighted combine.

This keeps every shape static (SPMD-compilable at 512 devices) without the
O(T·E·C) one-hot dispatch einsum, which is infeasible for deepseek's 64
experts at 4k sequences.  Tokens beyond an expert's capacity are dropped
(contribute zero), standard capacity-factor semantics.

Sharding: routed expert weights carry the "experts" logical axis (mapped to
the model mesh axis).  The baseline lets XLA place the scatter/gather
collectives; the EP hillclimb (§Perf) replaces them with an explicit
shard_map all_to_all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stability import stable_softmax
from repro.models.layers import ACTS
from repro.models.params import ParamSpec

__all__ = ["moe_spec", "moe"]


def moe_spec(cfg) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    spec = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.1),
        "routed": {
            "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
            "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
            "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed_out")),
        },
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        spec["shared"] = {
            "wi_gate": ParamSpec((d, fs), ("embed", "shared_mlp")),
            "wi_up": ParamSpec((d, fs), ("embed", "shared_mlp")),
            "wo": ParamSpec((fs, d), ("shared_mlp", "embed_out")),
        }
    return spec


def _expert_ffn(w, x, act):
    """x: (e, c, d) -> (e, c, d) via per-expert gated FFN."""
    f = ACTS[act]
    gate = jnp.einsum(
        "ecd,edf->ecf", x, w["wi_gate"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    up = jnp.einsum(
        "ecd,edf->ecf", x, w["wi_up"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    h = (f(gate) * up).astype(x.dtype)
    return jnp.einsum(
        "ecf,efd->ecd", h, w["wo"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def _group_dispatch(xt, expert_ids, gate_vals, w, cfg):
    """Per-sequence grouping: xt (t, d), ids/gates (t, k) -> (t, d).

    Runs under vmap over the batch axis, so the argsort stays local to one
    batch shard (no distributed sort under SPMD) and capacity is
    per-sequence — each row independently groups its tokens by expert.
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = int(t * k / e * cfg.capacity_factor) + 1

    flat_expert = expert_ids.reshape(-1)  # (t*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)  # stable
    se = jnp.take(flat_expert, order)
    st = jnp.take(flat_token, order)
    sg = jnp.take(flat_gate, order)
    # Position of each routed pair within its expert group.
    pos = jnp.arange(se.shape[0], dtype=se.dtype)
    group_start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_expert = pos - jnp.take(group_start, se)
    keep = pos_in_expert < cap
    slot = jnp.where(keep, se * cap + pos_in_expert, e * cap)  # overflow slot

    # Scatter tokens into expert batches (overflow row discarded).
    xe = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(jnp.take(xt, st, axis=0))
    ye = _expert_ffn(w, xe[:-1].reshape(e, cap, d), cfg.act)

    # Gather back with gate weighting.
    contrib = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), xt.dtype)], axis=0
    )
    weighted = jnp.take(contrib, slot, axis=0) * sg[:, None].astype(xt.dtype)
    return jnp.zeros((t, d), xt.dtype).at[st].add(
        jnp.where(keep[:, None], weighted, 0)
    )


def _einsum_dispatch(xt, expert_ids, gate_vals, w, cfg):
    """One-hot-matmul dispatch: same slot assignment as the scatter path,
    but tokens move via two dense einsums instead of scatter/gather.

    Under SPMD the scatter path replicates the vmapped batch dim (measured:
    ~6 GB/layer fp32 all-gathers of the dispatch buffers on grok-1 train —
    §Perf); the one-hot matmuls contract the token dim locally, costing
    O(t·k·e·cap·d) extra MXU flops but zero collectives.  Profitable when
    experts are few and wide (grok-1); the fine-grained deepseek layout
    keeps the scatter path (dispatch flops would triple its expert flops).
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = int(t * k / e * cfg.capacity_factor) + 1

    flat_expert = expert_ids.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se = jnp.take(flat_expert, order)
    st = jnp.take(flat_token, order)
    sg = jnp.take(flat_gate, order)
    pos = jnp.arange(se.shape[0], dtype=se.dtype)
    group_start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_expert = pos - jnp.take(group_start, se)
    keep = pos_in_expert < cap
    slot = jnp.where(keep, se * cap + pos_in_expert, e * cap)

    # (t*k, e*cap+1) routing matrix; dispatch/combine are dense matmuls.
    sm = jax.nn.one_hot(slot, e * cap + 1, dtype=xt.dtype)
    tok_oh = jax.nn.one_hot(st, t, dtype=xt.dtype)  # (t*k, t)
    xe = jnp.einsum(
        "rs,rt,td->sd", sm, tok_oh, xt, preferred_element_type=jnp.float32
    ).astype(xt.dtype)[:-1]
    ye = _expert_ffn(w, xe.reshape(e, cap, d), cfg.act)
    contrib = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), xt.dtype)], axis=0
    )
    wsm = sm * sg[:, None].astype(xt.dtype)
    return jnp.einsum(
        "rt,rs,sd->td", tok_oh, wsm, contrib,
        preferred_element_type=jnp.float32,
    ).astype(xt.dtype)


def moe(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (out, aux_loss). Routed top-k + optional shared path."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum(
        "bsd,de->bse", x, params["router"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(jnp.float32)
    probs = stable_softmax(logits, axis=-1)  # fp32 routing
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (b, s, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style), batch-mean.
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = e * jnp.sum(me * ce)

    use_einsum = getattr(cfg, "moe_dispatch", "scatter") == "einsum"
    dispatch = _einsum_dispatch if use_einsum else _group_dispatch
    # One-hot dispatch costs O(t * e*cap) = O(S^2) if grouped over the whole
    # sequence (cap grows with S) — fix the group length so the cost is
    # linear in S (per-group capacity, standard practice).  Scatter dispatch
    # is O(S log S) either way and keeps per-sequence groups.
    group = min(s, 1024) if use_einsum else s
    n_g = s // group if s % group == 0 else 1
    group = s // n_g

    def fold(z):
        return z.reshape((b * n_g, group) + z.shape[2:])

    out = jax.vmap(
        lambda xt, ids, gv: dispatch(xt, ids, gv, params["routed"], cfg)
    )(fold(x), fold(expert_ids), fold(gate_vals))
    out = out.reshape(b, s, d)

    if "shared" in params:
        from repro.models.layers import mlp

        out = out + mlp(params["shared"], x, cfg.act)
    return out, aux
