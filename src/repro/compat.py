"""Version-adaptive shims for jax APIs that moved between releases.

The codebase is written against the current jax surface —
``jax.enable_x64``, ``jax.set_mesh``, ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh`` — several of which do not exist on the
0.4.x line this container ships (``jax.enable_x64`` lives at
``jax.experimental.enable_x64`` there, ``shard_map`` at
``jax.experimental.shard_map`` with ``check_rep`` instead of
``check_vma``, and the context mesh is the legacy ``with mesh:`` resource
env).  Each symbol below resolves to the native implementation when the
installed jax has one and to a behavior-equivalent fallback otherwise.

:func:`install` (run on ``import repro``) additionally patches the missing
attributes onto ``jax`` itself so code that cannot import this module —
the subprocess snippets in ``tests/`` — runs unchanged.  On a recent jax
every shim resolves to the native symbol and ``install`` is a no-op.
"""

from __future__ import annotations

import enum
import functools
from typing import Any

import jax
from jax.experimental import enable_x64 as _experimental_enable_x64

__all__ = [
    "AxisType",
    "axis_size",
    "cost_analysis",
    "enable_x64",
    "get_abstract_mesh",
    "install",
    "make_mesh",
    "set_mesh",
    "shard_map",
]


if hasattr(jax, "enable_x64"):
    enable_x64 = jax.enable_x64
else:
    enable_x64 = _experimental_enable_x64


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def _make_mesh_supports_axis_types() -> bool:
    import inspect

    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return True  # uninspectable: assume current-jax signature


_native_make_mesh = jax.make_mesh

if _make_mesh_supports_axis_types():
    make_mesh = _native_make_mesh
else:

    @functools.wraps(_native_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types  # pre-AxisType jax: every axis is implicitly Auto
        return _native_make_mesh(axis_shapes, axis_names, **kw)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    # Legacy context mesh: enter the mesh's resource-env context manager
    # (never exited — process-global, matching jax.set_mesh semantics) so
    # with_sharding_constraint accepts bare PartitionSpecs under jit.
    _mesh_stack: list[Any] = []

    def set_mesh(mesh) -> None:
        _mesh_stack.append(mesh)
        mesh.__enter__()

    def get_abstract_mesh():
        """The active context mesh, or None.

        Returns the *concrete* mesh on legacy jax — callers only read
        ``axis_names`` / ``shape``, which the two types share.
        """
        if _mesh_stack:
            return _mesh_stack[-1]
        return None


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _legacy_shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=check_vma,
            **kw,
        )


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax release.

    0.4.x returns a list with one per-program dict; current jax returns the
    dict directly.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name) -> int:
        """Static size of a mapped axis.

        ``psum`` of a Python literal folds to ``literal * axis_size``
        without emitting a collective — the pre-``lax.axis_size`` idiom.
        """
        return jax.lax.psum(1, axis_name)


def install() -> None:
    """Patch the shims onto ``jax`` where the native symbols are missing."""
    for mod, name, value in [
        (jax, "enable_x64", enable_x64),
        (jax, "set_mesh", set_mesh),
        (jax, "shard_map", shard_map),
        (jax, "make_mesh", make_mesh),
        (jax.lax, "axis_size", axis_size),
        (jax.sharding, "AxisType", AxisType),
        (jax.sharding, "get_abstract_mesh", get_abstract_mesh),
    ]:
        # Modules with deprecation __getattr__ raise for removed names, so
        # hasattr is the correct "native symbol present" probe.
        if not hasattr(mod, name):
            setattr(mod, name, value)
    if jax.make_mesh is not make_mesh:
        jax.make_mesh = make_mesh
