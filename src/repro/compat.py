"""Version-adaptive shims for jax APIs that moved between releases.

The codebase is written against the current jax surface —
``jax.enable_x64``, ``jax.set_mesh``, ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh`` — several of which do not exist on the
0.4.x line this container ships (``jax.enable_x64`` lives at
``jax.experimental.enable_x64`` there, ``shard_map`` at
``jax.experimental.shard_map`` with ``check_rep`` instead of
``check_vma``, and the context mesh is the legacy ``with mesh:`` resource
env).  Each symbol below resolves to the native implementation when the
installed jax has one and to a behavior-equivalent fallback otherwise.

Which fallbacks are live is recorded per symbol at import time (before
anything can patch ``jax``) in the :func:`active_shims` set — the
version gate.  :func:`install` (run on ``import repro``) patches *only*
the symbols in that set onto ``jax`` itself, so code that cannot import
this module — the subprocess snippets in ``tests/`` — runs unchanged;
on a jax that provides a symbol natively the corresponding shim is
skipped entirely.  As jax upgrades, ``active_shims()`` can only shrink
(tests/test_compat.py holds it to the known 0.4.x full set), and on a
fully current jax it is empty and ``install`` is a no-op.
"""

from __future__ import annotations

import enum
import functools
from typing import Any

import jax
from jax.experimental import enable_x64 as _experimental_enable_x64

__all__ = [
    "AxisType",
    "active_shims",
    "axis_size",
    "cost_analysis",
    "enable_x64",
    "get_abstract_mesh",
    "install",
    "make_mesh",
    "set_mesh",
    "shard_map",
]

# Symbol -> "the running jax provides this natively", probed at module
# import (i.e. before install() can patch jax — the probes below and
# install() live in the same module, so the body always runs first).
# Modules with deprecation __getattr__ raise for removed names, so
# hasattr is the correct "native symbol present" probe.
_NATIVE: dict[str, bool] = {}


def active_shims() -> frozenset[str]:
    """Names of the 0.4.x fallbacks live in this process.

    Empty on a fully current jax; the full 0.4.x set on this container's
    jax.  A symbol in the set resolves to a fallback defined here (and
    ``install`` patches it onto ``jax``); a symbol not in the set
    resolves to — and is never patched over — the native jax one.
    """
    return frozenset(n for n, native in _NATIVE.items() if not native)


_NATIVE["enable_x64"] = hasattr(jax, "enable_x64")
if _NATIVE["enable_x64"]:
    enable_x64 = jax.enable_x64
else:
    enable_x64 = _experimental_enable_x64


_NATIVE["AxisType"] = hasattr(jax.sharding, "AxisType")
if _NATIVE["AxisType"]:
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def _make_mesh_supports_axis_types() -> bool:
    import inspect

    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return True  # uninspectable: assume current-jax signature


_native_make_mesh = jax.make_mesh

_NATIVE["make_mesh_axis_types"] = _make_mesh_supports_axis_types()
if _NATIVE["make_mesh_axis_types"]:
    make_mesh = _native_make_mesh
else:

    @functools.wraps(_native_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types  # pre-AxisType jax: every axis is implicitly Auto
        return _native_make_mesh(axis_shapes, axis_names, **kw)


_NATIVE["set_mesh"] = hasattr(jax, "set_mesh")
_NATIVE["get_abstract_mesh"] = _NATIVE["set_mesh"] and hasattr(
    jax.sharding, "get_abstract_mesh"
)
if _NATIVE["set_mesh"]:
    set_mesh = jax.set_mesh
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    # Legacy context mesh: enter the mesh's resource-env context manager
    # (never exited — process-global, matching jax.set_mesh semantics) so
    # with_sharding_constraint accepts bare PartitionSpecs under jit.
    _mesh_stack: list[Any] = []

    def set_mesh(mesh) -> None:
        _mesh_stack.append(mesh)
        mesh.__enter__()

    def get_abstract_mesh():
        """The active context mesh, or None.

        Returns the *concrete* mesh on legacy jax — callers only read
        ``axis_names`` / ``shape``, which the two types share.
        """
        if _mesh_stack:
            return _mesh_stack[-1]
        return None


_NATIVE["shard_map"] = hasattr(jax, "shard_map")
if _NATIVE["shard_map"]:
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _legacy_shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=check_vma,
            **kw,
        )


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax release.

    0.4.x returns a list with one per-program dict; current jax returns the
    dict directly.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


_NATIVE["axis_size"] = hasattr(jax.lax, "axis_size")
if _NATIVE["axis_size"]:
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name) -> int:
        """Static size of a mapped axis.

        ``psum`` of a Python literal folds to ``literal * axis_size``
        without emitting a collective — the pre-``lax.axis_size`` idiom.
        """
        return jax.lax.psum(1, axis_name)


def install() -> None:
    """Patch the fallbacks onto ``jax`` — only for the active shim set.

    Symbols the running jax provides natively are skipped entirely (the
    version gate): nothing native is ever patched over, and on a fully
    current jax this is a no-op.  Idempotent — re-running never
    re-patches or clobbers.
    """
    targets = {
        "enable_x64": (jax, "enable_x64", enable_x64),
        "set_mesh": (jax, "set_mesh", set_mesh),
        "shard_map": (jax, "shard_map", shard_map),
        "axis_size": (jax.lax, "axis_size", axis_size),
        "AxisType": (jax.sharding, "AxisType", AxisType),
        "get_abstract_mesh": (
            jax.sharding,
            "get_abstract_mesh",
            get_abstract_mesh,
        ),
    }
    shims = active_shims()
    for name, (mod, attr, value) in targets.items():
        if name in shims and not hasattr(mod, attr):
            setattr(mod, attr, value)
    # make_mesh exists natively on every supported jax; what 0.4.x lacks
    # is its axis_types parameter, so this one replaces rather than fills
    # a hole — gated on the same import-time probe.
    if "make_mesh_axis_types" in shims and jax.make_mesh is not make_mesh:
        jax.make_mesh = make_mesh
