"""Batched serving driver: prefill queue -> continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --slots 4 --requests 8 \
        --particles 4:32 --mesh 2x2 --async-admit]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``).  The SMC path is the engine
API end to end: decoding is expressed as an ``SMCSpec`` (one particle =
one partial sequence, its cache the state; propagation = sample a token;
weight = model log-prob at T=1) and served by a ``FilterBank`` — one slot
per in-flight request, ``--particles-per-slot`` particles each — under a
continuous-batching scheduler: requests are admitted into free slots
mid-flight, retired on completion, and the bank steps every tick regardless
of occupancy (the scheduler never waits to fill the batch and never
recompiles; slot lifecycle is ``reset_slot`` by traced index).

Particle budgets are per request (``--particles MIN:MAX``): the bank is a
*ragged* FilterBank at lane width MAX, each request draws a key-derived
power-of-two size class in [MIN, MAX] and is admitted at that traced
active count (``reset_slot(..., n_active=n)`` — no recompile per size),
so easy requests stop paying for the hardest request's particle cloud.
The scheduler reports the padding this removes from the quality ledger
(``padding_waste``: active vs padded particle-ticks).  A single
``--particles N`` (or the legacy ``--particles-per-slot``) keeps the dense
bank and its mask-free fast path.

``--elastic`` makes those budgets *starting points*: an ESS-driven
``BudgetController`` (``repro.core.elastic``) grows a slot whose ESS
collapses and shrinks one whose ESS is comfortably high, via the bank's
traced ``resize_slot`` budget switch (no recompiles), optionally under a
global particle budget arbitrated by ESS deficit (``--elastic-budget``).
Per-tick decisions are reported, and all padding/particle-tick accounting
follows the *current* budgets rather than admission-time ones.

The bank composes with a device mesh (``--mesh DxM``): slots shard over
the "data" axis and each slot's particles over "model" (the engine's
mesh × bank composition — ``repro.core.distributed.make_dist_bank_step``),
so the same scheduler serves a multi-device bank unchanged: admissions
place their traced-index reset onto the owning shard, and every retire
reads back only that request's slot.  ``--async-admit`` switches the
scheduler to the double-buffered path: the next bank step is dispatched
*before* the host blocks on the previous tick's counters, so admit/retire
bookkeeping (and the host↔device slot swaps it triggers) overlap device
compute instead of serializing with it — identical schedules, one step of
read-back lag.
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_smc_decode_spec(
    params, cfg, policy, decode, *, temperature: float, steps: int,
    prompt_len: int = 0,
):
    """SMC decoding as a particle-filter model.

    Particle state: token, KV/recurrent cache, last reward, token history.
    The transition runs one batched decode step and samples at the
    exploration temperature; the likelihood is the reward recorded by the
    transition (the model's own T=1 log-prob of the sampled token).
    ``gather`` selects ancestors along each cache leaf's *known* particle
    axis — derived once from the cache layout, never guessed from shapes
    (a dimension that merely equals the particle count, e.g. a layer or
    head count, must not be mistaken for the batch axis); ``summary`` keeps
    the per-step estimate to one scalar (mean reward) instead of averaging
    whole caches.  ``steps`` sizes the cache/history buffers — the *maximum*
    request length a serving slot can hold.

    ``prompt_len`` reserves room for a prompt processed by a *batched
    prefill pass* (:class:`PrefillRunner`) before admission: the cache is
    sized for prompt + decode, and decode positions are offset by
    ``prompt_len - 1`` so the first decode step lands right after the
    prefill writes (positions ``0..prompt_len-2``) with the last prompt
    token as the slot's starting ``tok``.  ``prompt_len=0`` (default) is
    exactly the promptless spec.
    """
    from repro.core.filter import SMCSpec
    from repro.models import model as M

    s_max = steps + prompt_len + 1
    # Per-leaf particle axis: the one dimension whose extent follows the
    # batch argument of the cache layout (shape-only — nothing allocated).
    cache_axes = jax.tree.map(
        lambda a, b: _changed_axis(a.shape, b.shape),
        M.cache_specs(cfg, 2, s_max),
        M.cache_specs(cfg, 3, s_max),
        is_leaf=_is_param_spec,
    )

    def init(key, n):
        del key
        return {
            "tok": jnp.zeros((n,), jnp.int32),
            "cache": M.init_cache(cfg, n, s_max, policy.compute_dtype),
            "reward": jnp.zeros((n,), jnp.float32),
            # Lineage log-prob: cumulative reward along the surviving
            # ancestry (travels through resampling gathers), since the
            # engine renormalizes the filter weights every step.
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        pos = step.astype(jnp.int32)
        if prompt_len:
            pos = pos + jnp.int32(prompt_len - 1)
        logits, cache = decode(params, p["tok"], pos, p["cache"])
        logits = logits.astype(jnp.float32)
        if temperature > 0:
            tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        logp = jax.nn.log_softmax(logits, -1)
        reward = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        # Freed slots keep ticking past their request's budget ("the bank
        # steps every tick"); clamp the history index so their writes land
        # on the last column instead of out of bounds.
        pos = jnp.minimum(step, steps - 1)
        return {
            "tok": tok,
            "cache": cache,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    def gather(p, anc):
        return {
            "tok": jnp.take(p["tok"], anc, axis=0),
            "cache": jax.tree.map(
                lambda x, ax: jnp.take(x, anc, axis=ax),
                p["cache"],
                cache_axes,
            ),
            "reward": jnp.take(p["reward"], anc, axis=0),
            "cum_reward": jnp.take(p["cum_reward"], anc, axis=0),
            "seq": jnp.take(p["seq"], anc, axis=0),
        }

    def summary(p, w):
        return {"reward": jnp.sum(w * p["reward"].astype(w.dtype))}

    return SMCSpec(
        init,
        transition,
        loglik,
        gather=gather,
        summary=summary,
        particle_axes={
            "tok": 0,
            "cache": cache_axes,
            "reward": 0,
            "cum_reward": 0,
            "seq": 0,
        },
    )


def _request_budgets(
    key: jax.Array, num_requests: int, min_steps: int, max_steps: int
) -> np.ndarray:
    """Per-request decode budgets in [min_steps, max_steps], keyed.

    The whole workload derives from the scheduler key — two seeds draw two
    schedules, one seed reproduces (the old hardcoded
    ``np.random.default_rng(0)`` made every ``--seed`` serve the same
    traffic).
    """
    return np.array(
        jax.random.randint(key, (num_requests,), min_steps, max_steps + 1)
    )


def particle_size_classes(p_min: int, p_max: int) -> list[int]:
    """Power-of-two ladder of particle budgets from p_min up to p_max.

    Requests are binned into these classes rather than arbitrary counts so
    a packer can group same-class requests (and so budgets stay friendly to
    lane-width-128 kernels): [p_min, 2*p_min, 4*p_min, ..., p_max].
    """
    if not 1 <= p_min <= p_max:
        raise ValueError(
            f"need 1 <= min <= max particle budgets, got {p_min}:{p_max}"
        )
    classes = []
    c = p_min
    while c < p_max:
        classes.append(c)
        c *= 2
    classes.append(p_max)
    return classes


def _request_particles(
    key: jax.Array, num_requests: int, p_min: int, p_max: int
) -> np.ndarray:
    """Key-derived per-request particle budgets drawn from the size-class
    ladder — the heterogeneous-difficulty workload of a ragged bank."""
    classes = np.asarray(particle_size_classes(p_min, p_max))
    idx = np.asarray(
        jax.random.randint(key, (num_requests,), 0, len(classes))
    )
    return classes[idx]


def make_packed_banks(spec, config, *, num_slots: int, p_min: int, p_max: int):
    """One width-matched FilterBank per particle size class.

    The multi-bank packing engine's bank family: ``num_slots`` total slots
    spread over the power-of-two ladder :func:`particle_size_classes`
    (remainder slots go to the *widest* classes — they double as the
    spillover and migration targets).  Banks after the first are built via
    :meth:`FilterBank.sibling`, so the family shares one set of jitted
    entry points — N class banks never N× compile.

    Returns ``{class_width: FilterBank}``, the packed-mode ``bank``
    argument of :func:`run_continuous_batching`.
    """
    from repro.core import FilterBank

    classes = particle_size_classes(p_min, p_max)
    if num_slots < len(classes):
        raise ValueError(
            f"packed banks need at least one slot per size class: "
            f"{num_slots} slots < {len(classes)} classes "
            f"{classes} (raise --slots or narrow --particles)"
        )
    base, rem = divmod(num_slots, len(classes))
    counts = {c: base for c in classes}
    for c in classes[len(classes) - rem:]:
        counts[c] += 1
    banks, donor = {}, None
    for c in classes:
        if donor is None:
            banks[c] = donor = FilterBank(spec, config, num_slots=counts[c])
        else:
            banks[c] = donor.sibling(num_slots=counts[c])
    return banks


class _Lane:
    """One width-matched FilterBank plus its scheduler-side mirrors.

    ``offset`` maps the lane's local slots into the packed scheduler's
    *global* slot space (``global = offset + slot``) — the index space the
    shared :class:`~repro.core.elastic.BudgetController`, the budget
    mirror, and every reported event use.
    """

    def __init__(self, bank, width: int, index: int, offset: int,
                 ragged: bool):
        self.bank = bank
        self.width = width
        self.index = index
        self.offset = offset
        self.ragged = ragged
        self.nb = bank.num_slots
        self.free = list(range(self.nb))[::-1]
        self.active: dict[int, dict] = {}
        self.obs = jnp.zeros((self.nb,), jnp.int32)
        self.state = None
        self.tick_ms: list[float] = []
        # (dispatch_time, FilterOutput) of the most recent step whose
        # read-backs have not been consumed yet (async latency tracking).
        self.prev = None

    def init_state(self, k_state: jax.Array):
        k = (
            k_state
            if self.index == 0
            else jax.random.fold_in(k_state, self.index)
        )
        if self.ragged:
            # Ragged states must be ragged from init (the pytree cannot
            # grow a count field under jit); empty slots idle at full
            # width.
            self.state = self.bank.init(
                k,
                self.width,
                n_active=jnp.full((self.nb,), self.width, jnp.int32),
            )
        else:
            self.state = self.bank.init(k, self.width)

    def step_keys(self, k_run: jax.Array, tick: int) -> jax.Array:
        base = jax.random.fold_in(k_run, tick)
        if self.index:
            base = jax.random.fold_in(base, self.index)
        return jax.random.split(base, self.nb)


class SizeClassPacker:
    """Width-ordered first-fit routing with work-conserving spillover.

    A request routes to the narrowest lane whose width fits its particle
    budget; when that home lane is full it may be *promoted* to the next
    wider lane with a free slot (admitted at its true budget — the extra
    lanes are charged as padding).  Scanning arrived requests in FIFO
    order with first-fit placement gives the work-conserving invariant:
    after an admission pass, no arrived request is still queued while any
    lane wide enough for it has a free slot — no bank idles while another
    queues.  A later small request may pass a blocked larger one (no
    head-of-line blocking), but never displaces a placeable one, and the
    scan order is deterministic: one seed, one schedule.
    """

    def __init__(self, lanes):
        self.lanes = sorted(lanes, key=lambda ln: ln.width)

    def place(self, budget: int):
        """The lane this request is admitted into, or None (all full)."""
        for lane in self.lanes:
            if lane.width >= budget and lane.free:
                return lane
        return None


class PrefillRunner:
    """Batched prompt prefill for SMC decode requests.

    The continuous-batching prefill/decode split: instead of each request
    burning its first in-slot ticks decoding the prompt token by token,
    every request admitted on a tick has its prompt processed as **one
    batched prefill pass** (positions ``0..prompt_len-2`` through the same
    jitted decode step the spec's transition uses, over a fixed-width
    request batch), and the resulting cache row is broadcast over the
    slot's particle lanes and uploaded via
    ``FilterBank.init_slot(particles=...)``.  The decode loop then starts
    at the last prompt token with a warm cache; the spec's position offset
    (``prompt_len - 1`` — see :func:`make_smc_decode_spec`) keeps decode
    writes contiguous with the prefill's.

    Prompts are key-derived per request (:meth:`make_prompts`, called by
    the scheduler from its workload key) — the serving analogue of the
    key-derived budgets.  The pass compiles once for the fixed
    ``(batch, prompt_len)`` block; short admission ticks pad the block by
    repeating the last request (wasted lanes, never wasted compiles).
    """

    def __init__(self, params, cfg, policy, decode, *,
                 prompt_len: int, steps: int, batch: int):
        from repro.models import model as M

        if prompt_len < 1:
            raise ValueError(
                f"prompt_len must be >= 1 (0 disables prefill), got "
                f"{prompt_len}"
            )
        if batch < 1:
            raise ValueError(f"prefill batch must be >= 1, got {batch}")
        self._M = M
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.decode = decode
        self.prompt_len = prompt_len
        self.steps = steps
        self.s_max = steps + prompt_len + 1
        self.batch = batch
        self.batches = 0
        self.prompts = None
        self.cache_axes = jax.tree.map(
            lambda a, b: _changed_axis(a.shape, b.shape),
            M.cache_specs(cfg, 2, self.s_max),
            M.cache_specs(cfg, 3, self.s_max),
            is_leaf=_is_param_spec,
        )
        self._pass = jax.jit(self._prefill_block)
        self._builders: dict[int, object] = {}

    def make_prompts(self, key: jax.Array, num_requests: int) -> None:
        """Key-derived (num_requests, prompt_len) token prompts."""
        self.prompts = jax.random.randint(
            key,
            (num_requests, self.prompt_len),
            0,
            self.cfg.vocab_size,
            dtype=jnp.int32,
        )

    def _prefill_block(self, block):
        """One batched prompt pass: (batch, L) tokens -> filled caches."""
        cache = self._M.init_cache(
            self.cfg, block.shape[0], self.s_max, self.policy.compute_dtype
        )
        if self.prompt_len == 1:
            return cache  # nothing precedes the starting token

        def body(cache, xs):
            tok, pos = xs
            _, cache = self.decode(self.params, tok, pos, cache)
            return cache, None

        cache, _ = jax.lax.scan(
            body,
            cache,
            (
                block[:, :-1].T,
                jnp.arange(self.prompt_len - 1, dtype=jnp.int32),
            ),
        )
        return cache

    def _rows_builder(self, width: int):
        """Jitted (cache_block, batch_index, tok) -> slot particle rows;
        the batch index stays traced, so one compile per lane width."""
        fn = self._builders.get(width)
        if fn is None:
            axes = self.cache_axes
            steps = self.steps

            def build(cache_block, r, tok):
                row_cache = jax.tree.map(
                    lambda x, ax: jnp.broadcast_to(
                        jax.lax.dynamic_index_in_dim(
                            x, r, axis=ax, keepdims=True
                        ),
                        x.shape[:ax] + (width,) + x.shape[ax + 1:],
                    ),
                    cache_block,
                    axes,
                )
                return {
                    "tok": jnp.full((width,), tok, jnp.int32),
                    "cache": row_cache,
                    "reward": jnp.zeros((width,), jnp.float32),
                    "cum_reward": jnp.zeros((width,), jnp.float32),
                    "seq": jnp.zeros((width, steps), jnp.int32),
                }

            fn = self._builders[width] = jax.jit(build)
        return fn

    def rows_for(self, ids: list[int], widths: list[int]) -> list:
        """Slot upload rows for one tick's admissions, batch-prefilled."""
        if self.prompts is None:
            raise ValueError(
                "PrefillRunner.make_prompts was never called (the "
                "scheduler derives prompts from its workload key)"
            )
        out = []
        for lo in range(0, len(ids), self.batch):
            chunk = ids[lo:lo + self.batch]
            pad = chunk + [chunk[-1]] * (self.batch - len(chunk))
            block = jnp.take(
                self.prompts, jnp.asarray(pad, jnp.int32), axis=0
            )
            cache_block = self._pass(block)
            self.batches += 1
            for j, rid in enumerate(chunk):
                out.append(
                    self._rows_builder(widths[lo + j])(
                        cache_block, jnp.int32(j), self.prompts[rid, -1]
                    )
                )
        return out


def _latency_summary(lanes, tick_deadline_ms):
    """p50/p95/max step wall-times per lane and pooled, plus the
    over-deadline count the future SLO-aware arbiter will consume."""
    per_bank, pooled = {}, []

    def _summ(ms):
        arr = np.asarray(ms, np.float64)
        over = (
            int((arr > tick_deadline_ms).sum())
            if tick_deadline_ms is not None
            else 0
        )
        return {
            "ticks": int(arr.size),
            "p50_ms": float(np.percentile(arr, 50)) if arr.size else 0.0,
            "p95_ms": float(np.percentile(arr, 95)) if arr.size else 0.0,
            "max_ms": float(arr.max()) if arr.size else 0.0,
            "ticks_over_deadline": over,
        }

    for lane in lanes:
        per_bank[lane.width] = _summ(lane.tick_ms)
        pooled.extend(lane.tick_ms)
    return {
        **_summ(pooled),
        "deadline_ms": tick_deadline_ms,
        "per_bank": per_bank,
    }


def run_continuous_batching(
    bank,
    *,
    num_requests: int,
    max_steps: int,
    particles: int | tuple[int, int],
    key: jax.Array,
    arrival_every: int = 1,
    min_steps: int | None = None,
    async_admit: bool = False,
    elastic=None,
    prefill=None,
    pipelined_uploads: bool = False,
    tick_deadline_ms: float | None = None,
) -> dict:
    """Admit → step → retire loop over a FilterBank of decode slots.

    Requests arrive on a fixed schedule (request ``i`` at tick
    ``i * arrival_every``) with key-derived budgets in
    [min_steps, max_steps].  A free slot is claimed by ``reset_slot``
    (traced slot index — no recompile); the whole bank steps every tick
    whether or not every slot holds a request; a slot retires the moment
    its step counter reaches its request's budget, returning the
    highest-cumulative-reward particle's sequence.  Works unchanged over a
    mesh-sharded bank (``FilterConfig(mesh=...)``): resets land on the
    owning shard, retires read back per-slot rows.

    ``particles`` may be a single count (dense bank, every request at the
    same width) or a ``(min, max)`` range — the *ragged* bank: the bank is
    built at lane width ``max``, every request draws a key-derived particle
    budget from the power-of-two size-class ladder in [min, max]
    (:func:`particle_size_classes`), and admission resets the slot at that
    *traced* count (``reset_slot(..., n_active=n)`` — no recompile per
    size).  Easy requests then carry e.g. 256 active lanes while hard ones
    carry 4096 in the same bank, and the returned stats report the padding
    this saves from the quality ledger: ``active_particle_ticks`` (lanes
    that did useful work), ``padded_particle_ticks`` (lanes a pad-to-max
    bank would bill), and ``padding_waste`` (their gap as a fraction).

    ``async_admit`` double-buffers the loop: each tick's bank step is
    dispatched *before* the host blocks on the previous tick's counters,
    so retire/extract bookkeeping overlaps device compute (the retiring
    slot's data is read from the already-materialized pre-step state — the
    in-flight step never gates a host decision).  While free slots exist
    the schedule matches the synchronous path tick for tick; when a
    request queues for a freed slot its admission lags by one tick — the
    price of never stalling the device on a host decision.  Returns
    per-request results plus occupancy/latency stats.

    ``elastic`` (an :class:`repro.core.elastic.ElasticConfig`, ragged banks
    only) turns admission budgets into *starting* budgets: a
    ``BudgetController`` watches each busy slot's per-step ESS (free from
    the fused epilogue's stats) and rewrites its active count in flight via
    ``bank.resize_slot`` — grow on ESS collapse, shrink when healthy, with
    deadband + cooldown and an optional global particle budget arbitrated
    by ESS deficit.  Synchronous ticks resize from the tick's own ESS;
    async ticks apply the *previous* tick's already-materialized ESS so
    the in-flight step is never waited on (one tick of controller lag, the
    same lag the scheduler already accepts for retires).  All particle-tick
    accounting (``active``/``padded``/``padding_waste``) and the retire
    read of the best particle use the slot's *current* budget from a host
    mirror updated at admit and at every granted resize, so the ledger
    stays truthful as budgets move mid-flight.  Decisions are returned in
    ``stats["elastic"]`` (per-event tick/slot/kind/ess/deficit plus
    grow/shrink/denied counters).

    **Packed mode** — pass ``bank`` as a ``{class_width: FilterBank}``
    family (:func:`make_packed_banks`) instead of one bank, and the
    scheduler becomes a multi-bank packing engine: each request routes to
    the narrowest bank whose lane width fits its particle budget
    (:class:`SizeClassPacker`), so an easy 256-particle request never
    pays a 4096-wide bank's lanes just because a hard request exists.
    Spillover is work-conserving: a request whose home class is full is
    promoted to the next wider bank with a free slot (admitted at its
    true budget, the extra lanes charged as padding in
    ``stats["packed"]["spillover_admissions"]``) rather than queueing
    while capacity idles.  Elastic resizes that cross a class boundary
    *migrate* the slot (``export_slot`` → masked-resample
    ``import_slot`` draw into the width-matched bank); a grow with no
    wide-enough free slot is reclassified as denied and backs off.
    Migration/spillover counts and per-class particle-tick ledgers land
    in ``stats["packed"]``.

    ``prefill`` (a :class:`PrefillRunner`) splits serving into
    prefill/decode: each tick's admissions run their prompts as one
    batched prefill pass and enter their slots with warm caches, so the
    decode loop never spends bank ticks re-reading prompts.
    ``pipelined_uploads`` (async mode only) moves admission/migration
    uploads to the tail of the tick, after the next step is already
    dispatch-eligible: slot-state uploads are enqueued against the
    in-flight step's output and overlap it, exactly like read-backs
    already do — the schedule (and every result) is bitwise identical to
    plain ``async_admit``; only the host timeline changes.

    Every tick's step wall-time is recorded per bank (dispatch→ready in
    sync mode, dispatch→consumption in async mode — the serving-relevant
    "how stale was this tick's data" number) and summarized in
    ``stats["latency"]`` as p50/p95/max per bank and pooled, plus
    ``ticks_over_deadline`` against ``tick_deadline_ms`` when given.
    """
    if min_steps is None:
        min_steps = max(1, max_steps // 2)
    if not 0 <= min_steps <= max_steps:
        raise ValueError(
            f"need 0 <= min_steps <= max_steps, got min_steps={min_steps}, "
            f"max_steps={max_steps}"
        )
    if isinstance(particles, tuple):
        p_min, p_max = particles
    else:
        p_min = p_max = particles
    ragged = p_min < p_max
    packed = isinstance(bank, dict)
    if packed:
        widths = sorted(bank)
        expect = particle_size_classes(p_min, p_max)
        if widths != expect:
            raise ValueError(
                f"packed bank widths {widths} do not match the size-class "
                f"ladder {expect} for particles=({p_min}, {p_max}) — build "
                f"them with make_packed_banks"
            )
    if pipelined_uploads and not async_admit:
        raise ValueError(
            "pipelined_uploads overlaps uploads with the in-flight step — "
            "it requires async_admit=True"
        )
    # Every lane in a multi-class family is ragged: spillover and
    # migration put narrower-budget requests into wider banks, so even
    # the widest class needs runtime counts.  A single-class family is a
    # plain dense bank wearing the packed API — kept dense so it stays
    # bitwise identical to the single-bank path.
    packed_multi = packed and len(bank) > 1
    lanes: list[_Lane] = []
    if packed:
        offset = 0
        for i, w in enumerate(sorted(bank)):
            lane = _Lane(bank[w], w, i, offset, ragged=packed_multi or ragged)
            lanes.append(lane)
            offset += lane.nb
    else:
        lanes.append(_Lane(bank, p_max, 0, 0, ragged=ragged))
    packer = SizeClassPacker(lanes)
    total_slots = sum(lane.nb for lane in lanes)
    ctrl = None
    if elastic is not None:
        from repro.core.elastic import BudgetController

        if not (ragged or packed_multi):
            raise ValueError(
                "elastic budgets need a ragged bank: pass "
                "particles=(MIN, MAX) with MIN < MAX so per-slot counts "
                "are runtime values"
            )
        if elastic.max_particles > p_max:
            raise ValueError(
                f"elastic.max_particles={elastic.max_particles} exceeds "
                f"the bank's lane width {p_max}"
            )
        ctrl = BudgetController(elastic, total_slots)
    k_state, k_admit, k_run, k_sched, k_elastic = jax.random.split(key, 5)
    lengths = _request_budgets(k_sched, num_requests, min_steps, max_steps)
    if ragged:
        budgets = _request_particles(
            jax.random.fold_in(k_sched, 1), num_requests, p_min, p_max
        )
    else:
        budgets = np.full((num_requests,), p_max)
    if prefill is not None:
        prefill.make_prompts(jax.random.fold_in(k_sched, 2), num_requests)
    pending = collections.deque(
        {
            "id": i,
            "steps": int(lengths[i]),
            "particles": int(budgets[i]),
            "arrival": i * arrival_every,
        }
        for i in range(num_requests)
    )
    for lane in lanes:
        lane.init_state(k_state)
        # Synchronous ticks donate the bank state: step and admission
        # reuse the particle/weight/cache buffers in place instead of
        # copying them every tick (the pre-step state is never read after
        # the call).  The async path must NOT donate its step input —
        # retire reads the *pre-step* state while the step runs on
        # device, so aliasing those buffers would hand retire reclaimed
        # memory.
        lane.step_fn = (
            lane.bank.jit_step if async_admit else lane.bank.jit_step_donated
        )

    # Global slot space: lane i's slot s is global slot lane.offset + s.
    # The controller, the budget mirror, and every event speak global ids.
    lane_of: list[_Lane] = []
    for lane in lanes:
        lane_of.extend([lane] * lane.nb)
    lane_width_vec = np.asarray([lane.width for lane in lane_of], np.int64)
    results, tick, busy_slot_ticks = [], 0, 0
    active_particle_ticks, padded_particle_ticks = 0, 0
    # Host mirror of each slot's *current* particle budget.  Admission
    # seeds it; every granted elastic resize updates it; all particle-tick
    # accounting and retire reads go through it instead of the
    # admission-time ``req["particles"]`` (stale once budgets move).
    slot_budget = np.zeros(total_slots, np.int64)
    events: list[dict] = []
    packed_stats = {
        "spillover_admissions": 0,
        "migrations": 0,
        "migrations_blocked": 0,
        "lane_particle_ticks": 0,
    }

    def admit_all(tick):
        """One admission pass: route every arrived request via the packer.

        FIFO over arrivals with first-fit placement — work-conserving: a
        request only stays queued if *no* wide-enough lane has a free
        slot, and a blocked large request never blocks a placeable small
        one behind it (unplaceable requests are deferred back to the
        queue head in order).  With prefill active, the tick's admissions
        run their prompts as one batched pass before any slot upload.
        """
        arrived = []
        while pending and pending[0]["arrival"] <= tick:
            arrived.append(pending.popleft())
        placed, deferred = [], []
        for req in arrived:
            lane = packer.place(req["particles"])
            if lane is None:
                deferred.append(req)
                continue
            placed.append((req, lane, lane.free.pop()))
        for req in reversed(deferred):
            pending.appendleft(req)
        rows = None
        if prefill is not None and placed:
            rows = prefill.rows_for(
                [req["id"] for req, _, _ in placed],
                [lane.width for _, lane, _ in placed],
            )
        for j, (req, lane, slot) in enumerate(placed):
            k = jax.random.fold_in(k_admit, req["id"])
            if lane.ragged and rows is not None:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state,
                    jnp.int32(slot),
                    k,
                    jnp.int32(req["particles"]),
                    rows[j],
                )
            elif lane.ragged:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state,
                    jnp.int32(slot),
                    k,
                    jnp.int32(req["particles"]),
                )
            elif rows is not None:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state, jnp.int32(slot), k, None, rows[j]
                )
            else:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state, jnp.int32(slot), k
                )
            req["admitted_tick"] = tick
            lane.active[slot] = req
            g = lane.offset + slot
            slot_budget[g] = req["particles"]
            if packed_multi and lane.width > req["particles"]:
                # Promoted past its home class: admitted at its true
                # budget, the lane's extra width is charged as padding.
                packed_stats["spillover_admissions"] += 1
            if ctrl is not None:
                # Grace period: a fresh request's first ESS readings are
                # noise; hold resizes for one full cooldown window.
                ctrl.slot_admitted(g)

    def retire(lane, ex_state, ex_tick):
        """Retire against a state holding ``ex_tick`` completed steps."""
        if not lane.active:
            return
        steps_now = np.asarray(ex_state.step)
        done = [
            s
            for s in lane.active
            if lane.active[s]["admitted_tick"] < ex_tick
            and steps_now[s] >= lane.active[s]["steps"]
        ]
        if not done:
            return
        cum = np.asarray(ex_state.particles["cum_reward"], np.float32)
        seqs = np.asarray(ex_state.particles["seq"])
        for slot in done:
            req = lane.active.pop(slot)
            # Best particle over the slot's *currently active* lanes only —
            # lanes beyond the current budget hold junk (a shrunk slot's
            # old lanes included) that must never win the argmax.
            n_now = int(slot_budget[lane.offset + slot])
            best = int(np.argmax(cum[slot, :n_now]))
            res = {
                "id": req["id"],
                "steps": req["steps"],
                "particles": req["particles"],
                "final_particles": n_now,
                # A real copy, not a view: np.asarray above is
                # zero-copy into the jax buffer, and a live external
                # view would block the donated step/reset from
                # aliasing the bank state on every later tick (and
                # pin the whole (nb, P, steps) seq array per retired
                # request until the run ends).
                "tokens": np.array(seqs[slot, best, : req["steps"]]),
                "admitted_tick": req["admitted_tick"],
                "finished_tick": ex_tick,
            }
            if packed:
                res["lane_width"] = lane.width
            results.append(res)
            lane.free.append(slot)

    def migrate(src, slot, dst, d, k, ev):
        """Move one live slot across banks: export → width-matched import.

        The export is a non-destructive read of the source's current
        (post-step) state; its outputs are fresh buffers, so the donated
        import and every later donated op on the source state stay safe.
        """
        rows, lw_row, step_row = src.bank.jit_export_slot(
            src.state, jnp.int32(slot)
        )
        dslot = dst.free.pop()
        dst.state = dst.bank.jit_import_slot_donated(
            dst.state,
            jnp.int32(dslot),
            rows,
            lw_row,
            k,
            jnp.int32(d.new),
            step_row,
        )
        req = src.active.pop(slot)
        dst.active[dslot] = req
        src.free.append(slot)
        g_src, g_dst = src.offset + slot, dst.offset + dslot
        # Cooldown/collapse history travels with the request.
        ctrl.slot_moved(g_src, g_dst)
        slot_budget[g_dst] = d.new
        slot_budget[g_src] = 0
        packed_stats["migrations"] += 1
        ev["migrated_to"] = g_dst
        ev["from_width"] = src.width
        ev["to_width"] = dst.width

    def apply_elastic(ess, tick):
        """Run one controller tick and apply granted decisions in place.

        Each lane's ``state`` here is its freshest (post-step) state and
        nothing else reads it afterward, so the donated resize / reseed /
        import are safe.  Decisions whose new count crosses a class
        boundary migrate (grow: ``migrate=True`` from the controller;
        shrink: repacked downward whenever a narrower class has room);
        everything else resizes in place.
        """
        busy_mask = np.zeros(total_slots, bool)
        for lane in lanes:
            for s in lane.active:
                busy_mask[lane.offset + s] = True
        decisions = ctrl.observe(
            ess,
            slot_budget,
            busy_mask,
            lane_width=lane_width_vec if packed_multi else None,
        )
        for d in decisions:
            ev = {
                "tick": tick,
                "slot": d.slot,
                "old": d.old,
                "new": d.new,
                "ess": d.ess,
                "kind": d.kind,
                "granted": d.granted,
                "deficit": d.deficit,
            }
            events.append(ev)
            if not d.granted:
                continue
            k = jax.random.fold_in(k_elastic, len(events))
            lane = lane_of[d.slot]
            slot = d.slot - lane.offset
            if d.kind == "reseed":
                # Collapse recovery: fresh diffuse cloud at the slot's
                # max budget, progress kept — a restart, not a retire.
                lane.state = lane.bank.jit_reseed_slot_donated(
                    lane.state, jnp.int32(slot), k, jnp.int32(d.new)
                )
                slot_budget[d.slot] = d.new
                continue
            if packed_multi and d.migrate:
                dst = next(
                    (
                        ln
                        for ln in packer.lanes
                        if ln.width >= d.new and ln.free
                    ),
                    None,
                )
                if dst is None:
                    # No wide-enough free slot anywhere: the controller
                    # reclassifies the grow as denied and keeps the
                    # cooldown charged (backoff before retrying).
                    ctrl.migration_blocked(d.slot)
                    packed_stats["migrations_blocked"] += 1
                    ev["granted"] = False
                    ev["blocked"] = True
                    continue
                migrate(lane, slot, dst, d, k, ev)
                continue
            if packed_multi and d.kind == "shrink":
                dst = next(
                    (
                        ln
                        for ln in packer.lanes
                        if ln.width >= d.new and ln.free
                    ),
                    None,
                )
                if dst is not None and dst.width < lane.width:
                    # Repack downward: the shrunk request no longer needs
                    # this class's width and a narrower bank has room.
                    migrate(lane, slot, dst, d, k, ev)
                    continue
            lane.state = lane.bank.jit_resize_slot_donated(
                lane.state, jnp.int32(slot), k, jnp.int32(d.new)
            )
            slot_budget[d.slot] = d.new

    def consume_prev(lane):
        """Block on the lane's previous in-flight step (async modes):
        returns its ESS row and records dispatch→consumption latency."""
        if lane.prev is None:
            return None
        t0, out = lane.prev
        lane.prev = None
        ess = np.asarray(out.ess, np.float64)
        lane.tick_ms.append((time.perf_counter() - t0) * 1e3)
        return ess

    if pipelined_uploads:
        # Pipelined mode admits at the *tail* of each tick, so tick 0's
        # arrivals need a pass before the first dispatch.
        admit_all(tick)
    while pending or any(lane.active for lane in lanes):
        if not pipelined_uploads:
            admit_all(tick)
        dispatches = []
        for lane in lanes:
            keys = lane.step_keys(k_run, tick)
            # Per-tick particle accounting from the *current* budgets
            # (the host mirror), not admission-time ones: under elastic
            # resizes the admission budget is only where a request
            # started.
            busy = [
                int(slot_budget[lane.offset + s]) for s in lane.active
            ]
            t0 = time.perf_counter()
            post, out = lane.step_fn(lane.state, lane.obs, keys)
            dispatches.append((lane, busy, t0, post, out))
        if async_admit:
            # Dispatch-first, decide later: the retire pass blocks only
            # on the *pre-step* state (already materialized), and the
            # latency/ESS consumption blocks only on the *previous*
            # tick's step, while this tick's steps run on device.
            prev_rows = []
            for lane, busy, t0, post, out in dispatches:
                busy_slot_ticks += len(busy)
                active_particle_ticks += sum(busy)
                padded_particle_ticks += len(busy) * p_max
                packed_stats["lane_particle_ticks"] += len(busy) * lane.width
                prev_rows.append(consume_prev(lane))
                retire(lane, lane.state, tick)
            for lane, busy, t0, post, out in dispatches:
                lane.state = post
                lane.prev = (t0, out)
            if ctrl is not None and prev_rows and prev_rows[0] is not None:
                # One tick of lag: resize from the previous step's ESS
                # (already materialized) so the in-flight step is never
                # waited on; the resizes apply to its output.
                apply_elastic(np.concatenate(prev_rows), tick)
            tick += 1
            if pipelined_uploads:
                # Tail admissions: uploads enqueue against the in-flight
                # step's output and overlap it — the next dispatch finds
                # its slots already written, never a host admission stall.
                admit_all(tick)
        else:
            tick += 1
            ess_rows = []
            for lane, busy, t0, post, out in dispatches:
                lane.state = post
                ess = np.asarray(out.ess, np.float64)
                lane.tick_ms.append((time.perf_counter() - t0) * 1e3)
                busy_slot_ticks += len(busy)
                active_particle_ticks += sum(busy)
                padded_particle_ticks += len(busy) * p_max
                packed_stats["lane_particle_ticks"] += len(busy) * lane.width
                retire(lane, lane.state, tick)
                ess_rows.append(ess)
            if ctrl is not None:
                apply_elastic(np.concatenate(ess_rows), tick)
    for lane in lanes:
        consume_prev(lane)  # final in-flight step's latency sample
    results.sort(key=lambda r: r["id"])
    stats = {
        "results": results,
        "ticks": tick,
        "busy_slot_ticks": busy_slot_ticks,
        "occupancy": busy_slot_ticks / max(1, tick * total_slots),
        "active_particle_ticks": active_particle_ticks,
        "padded_particle_ticks": padded_particle_ticks,
        "padding_waste": (
            1.0 - active_particle_ticks / padded_particle_ticks
            if padded_particle_ticks
            else 0.0
        ),
        "elastic": (
            {"events": events, **ctrl.stats} if ctrl is not None else None
        ),
        "latency": _latency_summary(lanes, tick_deadline_ms),
        "packed": (
            {
                "classes": {lane.width: lane.nb for lane in lanes},
                **packed_stats,
            }
            if packed
            else None
        ),
        "prefill": (
            {"prompt_len": prefill.prompt_len, "batches": prefill.batches}
            if prefill is not None
            else None
        ),
    }
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling, continuous-batched "
                         "(one FilterBank slot per request)")
    ap.add_argument("--slots", type=int, default=4,
                    help="--smc: concurrent request slots in the bank")
    ap.add_argument("--requests", type=int, default=8,
                    help="--smc: total requests to serve")
    ap.add_argument("--particles-per-slot", type=int, default=4)
    ap.add_argument("--particles", default="",
                    help="--smc: per-request particle budgets, either a "
                         "single count or a MIN:MAX range (ragged bank: "
                         "the bank runs at lane width MAX and each request "
                         "draws a key-derived power-of-two size class in "
                         "[MIN, MAX]); overrides --particles-per-slot")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="--smc: ticks between request arrivals")
    ap.add_argument("--elastic", action="store_true",
                    help="--smc ragged banks: ESS-driven per-slot particle "
                         "budget autoscaling — grow a slot on ESS collapse, "
                         "shrink when ESS is healthy (see --elastic-*)")
    ap.add_argument("--elastic-grow", type=float, default=None,
                    help="absolute ESS floor that doubles a busy slot's "
                         "budget (default: MIN/2 from --particles)")
    ap.add_argument("--elastic-shrink", type=float, default=None,
                    help="absolute ESS ceiling that halves a busy slot's "
                         "budget (default: 4x the grow floor)")
    ap.add_argument("--elastic-cooldown", type=int, default=2,
                    help="ticks a slot is frozen after a granted resize")
    ap.add_argument("--elastic-budget", type=int, default=None,
                    help="global cap on total active particles across "
                         "busy slots; grows beyond it are denied in "
                         "ESS-deficit order (default: uncapped)")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--mesh", default="",
                    help="--smc: DxM device mesh, e.g. 2x2 — slots shard "
                         "over 'data' (D), particles over 'model' (M); "
                         "needs D*M visible devices")
    ap.add_argument("--scheme", default="local",
                    choices=["exact", "local"],
                    help="--smc --mesh: distributed resampling scheme")
    ap.add_argument("--async-admit", action="store_true",
                    help="--smc: double-buffered admit/retire overlapping "
                         "the bank step")
    ap.add_argument("--packed", action="store_true",
                    help="--smc: one width-matched bank per particle size "
                         "class (size-class packing) instead of one "
                         "pad-to-MAX bank; requests route to the narrowest "
                         "fitting bank with work-conserving spillover, and "
                         "elastic resizes crossing a class boundary migrate "
                         "the slot across banks")
    ap.add_argument("--prompt-len", type=int, default=0,
                    help="--smc: prompt tokens per request, processed as "
                         "one batched prefill pass per admission tick "
                         "before the slot enters the decode loop "
                         "(0 disables the prefill/decode split)")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="--smc --prompt-len: requests per batched prefill "
                         "pass (default: --slots)")
    ap.add_argument("--pipelined-uploads", action="store_true",
                    help="--smc --async-admit: enqueue admission/migration "
                         "slot uploads behind the in-flight bank step "
                         "instead of ahead of the next dispatch (bitwise "
                         "identical schedule, host never stalls on uploads)")
    ap.add_argument("--tick-deadline-ms", type=float, default=None,
                    help="--smc: per-tick step latency deadline; the "
                         "summary reports p50/p95 and ticks over it")
    ap.add_argument("--elastic-reseed-after", type=int, default=None,
                    help="--smc --elastic: consecutive collapsed ticks "
                         "(ESS under the grow floor at max_particles) "
                         "before the slot is re-seeded from the prior "
                         "(default: --elastic-cooldown; 0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterBank, FilterConfig
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    t0 = time.perf_counter()
    if args.smc:
        spec = make_smc_decode_spec(
            params, cfg, policy, decode,
            temperature=args.temperature, steps=args.steps,
            prompt_len=args.prompt_len,
        )
        # Engine resampling criterion: ESS < frac * particles, exact
        # comparison (frac >= 1 -> resample every step).  With --mesh the
        # bank shards slots x particles over data x model and the
        # distributed scheme resamples every step.
        mesh = None
        if args.mesh:
            from repro import compat

            d, m = (int(x) for x in args.mesh.lower().split("x"))
            mesh = compat.make_mesh(
                (d, m),
                ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2,
            )
        particles = _parse_particles(args)
        p_min, p_max = (
            particles
            if isinstance(particles, tuple)
            else (particles, particles)
        )
        fconfig = FilterConfig(
            policy=policy,
            ess_threshold=args.ess_frac,
            mesh=mesh,
            scheme=args.scheme,
        )
        if args.packed:
            bank = make_packed_banks(
                spec, fconfig,
                num_slots=args.slots, p_min=p_min, p_max=p_max,
            )
        else:
            bank = FilterBank(spec, fconfig, num_slots=args.slots)
        elastic = None
        if args.elastic:
            from repro.core.elastic import ElasticConfig

            if not isinstance(particles, tuple):
                raise SystemExit(
                    "--elastic needs a ragged bank: pass --particles "
                    "MIN:MAX with MIN < MAX"
                )
            grow = (
                args.elastic_grow
                if args.elastic_grow is not None
                else particles[0] / 2
            )
            reseed = args.elastic_reseed_after
            if reseed is None:
                reseed = args.elastic_cooldown
            elastic = ElasticConfig(
                grow_below=grow,
                shrink_above=args.elastic_shrink,
                cooldown=args.elastic_cooldown,
                min_particles=particles[0],
                max_particles=particles[1],
                global_budget=args.elastic_budget,
                reseed_after=reseed or None,
            )
        prefill = None
        if args.prompt_len:
            prefill = PrefillRunner(
                params, cfg, policy, decode,
                prompt_len=args.prompt_len,
                steps=args.steps,
                batch=args.prefill_batch or args.slots,
            )
        stats = run_continuous_batching(
            bank,
            num_requests=args.requests,
            max_steps=args.steps,
            particles=particles,
            key=jax.random.key(args.seed),
            arrival_every=args.arrival_every,
            async_admit=args.async_admit,
            elastic=elastic,
            prefill=prefill,
            pipelined_uploads=args.pipelined_uploads,
            tick_deadline_ms=args.tick_deadline_ms,
        )
        dt = time.perf_counter() - t0
        n_steps = sum(r["steps"] for r in stats["results"])
        ticks = max(1, stats["ticks"])
        pdesc = (
            f"{particles[0]}:{particles[1]}"
            if isinstance(particles, tuple)
            else str(particles)
        )
        print(
            f"arch={cfg.name} smc slots={args.slots} "
            f"requests={args.requests} particles/slot={pdesc}"
            + (f" mesh={args.mesh} scheme={args.scheme}" if mesh else "")
            + (" async" if args.async_admit else "")
            + (" pipelined" if args.pipelined_uploads else "")
            + (" packed" if args.packed else "")
            + (" elastic" if elastic is not None else "")
            + (f" prefill={args.prompt_len}" if prefill is not None else "")
            + f" ticks={stats['ticks']} "
            f"occupancy={stats['occupancy']:.0%} "
            f"padding_waste={stats['padding_waste']:.0%} "
            f"({dt / ticks * 1e3:.1f} ms/tick incl. compile, "
            f"{n_steps / dt:.1f} request-steps/s)"
        )
        lat = stats["latency"]
        print(
            f"  latency: p50={lat['p50_ms']:.2f}ms p95={lat['p95_ms']:.2f}ms "
            f"max={lat['max_ms']:.2f}ms over {lat['ticks']} step samples"
            + (
                f"; over_deadline={lat['ticks_over_deadline']} "
                f"(deadline={lat['deadline_ms']:.1f}ms)"
                if lat["deadline_ms"] is not None
                else ""
            )
        )
        pk = stats["packed"]
        if pk is not None:
            classes = " ".join(
                f"{w}p x{n}" for w, n in sorted(pk["classes"].items())
            )
            print(
                f"  packed: classes=[{classes}] "
                f"spillover={pk['spillover_admissions']} "
                f"migrations={pk['migrations']} "
                f"blocked={pk['migrations_blocked']}"
            )
        pf = stats["prefill"]
        if pf is not None:
            print(
                f"  prefill: prompt_len={pf['prompt_len']} "
                f"batched_passes={pf['batches']}"
            )
        el = stats["elastic"]
        if el is not None:
            print(
                f"  elastic: grows={el['grows']} shrinks={el['shrinks']} "
                f"denied_grows={el['denied_grows']} "
                f"reseeds={el['reseeds']} "
                f"global_budget={args.elastic_budget or 'uncapped'}"
            )
            for e in el["events"][:8]:
                print(
                    f"    tick {e['tick']:>3} slot {e['slot']}: "
                    f"{e['kind']} {e['old']}->{e['new']} "
                    f"ess={e['ess']:.1f}"
                    + (
                        f" deficit={e['deficit']:.1f}"
                        if e["kind"] == "grow"
                        else ""
                    )
                    + ("" if e["granted"] else " DENIED")
                )
            if len(el["events"]) > 8:
                print(f"    ... {len(el['events']) - 8} more events")
        for r in stats["results"][:4]:
            pdesc2 = str(r["particles"])
            if r["final_particles"] != r["particles"]:
                pdesc2 += f"->{r['final_particles']}"
            print(
                f"  req[{r['id']}] steps={r['steps']} "
                f"particles={pdesc2} "
                f"latency={r['finished_tick'] - r['admitted_tick']} ticks: "
                f"{r['tokens'][:12].tolist()}..."
            )
        return
    cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
    tok = jnp.zeros((b,), jnp.int32)
    seqs = np.zeros((b, args.steps), np.int32)
    key = jax.random.key(args.seed)
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        key, k1 = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                k1, logits / args.temperature, -1
            )
        else:
            tok = jnp.argmax(logits, -1)
        seqs[:, i] = np.asarray(tok)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} independent batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)")
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _parse_particles(args) -> int | tuple[int, int]:
    """``--particles`` ("N" or "MIN:MAX") with --particles-per-slot fallback."""
    if not args.particles:
        return args.particles_per_slot
    spec = args.particles
    if ":" in spec:
        lo, hi = (int(x) for x in spec.split(":", 1))
        if not 1 <= lo <= hi:
            raise SystemExit(
                f"--particles range must satisfy 1 <= MIN <= MAX, got {spec}"
            )
        return lo if lo == hi else (lo, hi)
    return int(spec)


def _batch_axis(x, n):
    """The unique axis of extent ``n`` — raises when absent *or ambiguous*.

    Guessing "first dimension equal to n" silently picks the wrong axis for
    square shapes (batch == seq-len, batch == num-layers, ...); callers
    that know the layout should thread the axis through instead (see
    ``make_smc_decode_spec``'s cache_axes).
    """
    hits = [i for i, d in enumerate(x.shape) if d == n]
    if not hits:
        raise ValueError(f"no batch axis of extent {n} in {x.shape}")
    if len(hits) > 1:
        raise ValueError(
            f"ambiguous batch axis: {len(hits)} dimensions of extent {n} "
            f"in {x.shape} (axes {hits}); thread the known axis through "
            "instead of guessing"
        )
    return hits[0]


def _changed_axis(shape_a: tuple, shape_b: tuple) -> int:
    """The single axis on which two layouts of different batch size differ."""
    diff = [i for i, (a, b) in enumerate(zip(shape_a, shape_b)) if a != b]
    if len(diff) != 1:
        raise ValueError(
            f"expected exactly one batch-dependent axis, got {diff} "
            f"between {shape_a} and {shape_b}"
        )
    return diff[0]


def _is_param_spec(x) -> bool:
    from repro.models.params import ParamSpec

    return isinstance(x, ParamSpec)


if __name__ == "__main__":
    main()
