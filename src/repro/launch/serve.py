"""Batched serving driver: prefill queue -> synchronized decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --particles-per-slot 4]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``: systematic resampling of
sequence states by model log-prob, the SMC decoding from
examples/smc_decode.py behind a production-style driver).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling (systematic resampling)")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import resampling, stability
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    tok = jnp.zeros((b,), jnp.int32)
    log_w = jnp.full((b,), -jnp.log(float(b)), jnp.float32)
    seqs = np.zeros((b, args.steps), np.int32)
    key = jax.random.key(args.seed)
    t0 = time.perf_counter()
    n_resample = 0
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        key, k1, k2 = jax.random.split(key, 3)
        if args.temperature > 0:
            tok = jax.random.categorical(k1, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        seqs[:, i] = np.asarray(tok)
        if args.smc:
            logp = jax.nn.log_softmax(logits, -1)
            log_w = log_w + jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
            w, _ = stability.normalize_log_weights(log_w)
            ess = float(stability.effective_sample_size(w))
            if ess < args.ess_frac * b:
                anc = resampling.systematic(k2, w, get_policy("fp32"))
                tok = jnp.take(tok, anc, 0)
                cache = jax.tree.map(
                    lambda x: jnp.take(x, anc, axis=_batch_axis(x, b)), cache
                )
                seqs = seqs[np.asarray(anc)]
                log_w = jnp.full((b,), -jnp.log(float(b)), jnp.float32)
                n_resample += 1
    dt = time.perf_counter() - t0
    mode = "smc" if args.smc else "independent"
    print(f"arch={cfg.name} {mode} batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)"
          + (f" resamples={n_resample}" if args.smc else ""))
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _batch_axis(x, n):
    for i, d in enumerate(x.shape):
        if d == n:
            return i
    raise ValueError(f"no batch axis in {x.shape}")


if __name__ == "__main__":
    main()
