"""Batched serving driver: prefill queue -> synchronized decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --particles-per-slot 4]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``).  The SMC path is the engine
API end to end: decoding is expressed as an ``SMCSpec`` (one particle =
one partial sequence, its cache the state; propagation = sample a token;
weight = model log-prob at T=1) and driven by
``ParticleFilter.stream`` — the same engine that runs the object tracker,
with adaptive systematic resampling batch-gathering the cache states.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_smc_decode_spec(
    params, cfg, policy, decode, *, temperature: float, steps: int
):
    """SMC decoding as a particle-filter model.

    Particle state: token, KV/recurrent cache, last reward, token history.
    The transition runs one batched decode step and samples at the
    exploration temperature; the likelihood is the reward recorded by the
    transition (the model's own T=1 log-prob of the sampled token).
    ``gather`` locates the particle axis per cache leaf; ``summary`` keeps
    the per-step estimate to one scalar (mean reward) instead of averaging
    whole caches.
    """
    from repro.core.filter import SMCSpec
    from repro.models import model as M

    def init(key, n):
        del key
        return {
            "tok": jnp.zeros((n,), jnp.int32),
            "cache": M.init_cache(cfg, n, steps + 1, policy.compute_dtype),
            "reward": jnp.zeros((n,), jnp.float32),
            # Lineage log-prob: cumulative reward along the surviving
            # ancestry (travels through resampling gathers), since the
            # engine renormalizes the filter weights every step.
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        logits, cache = decode(
            params, p["tok"], step.astype(jnp.int32), p["cache"]
        )
        logits = logits.astype(jnp.float32)
        if temperature > 0:
            tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        logp = jax.nn.log_softmax(logits, -1)
        reward = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        return {
            "tok": tok,
            "cache": cache,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, step].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    def gather(p, anc):
        n = p["tok"].shape[0]
        take = lambda x: jnp.take(x, anc, axis=_batch_axis(x, n))  # noqa: E731
        return {
            "tok": jnp.take(p["tok"], anc, axis=0),
            "cache": jax.tree.map(take, p["cache"]),
            "reward": jnp.take(p["reward"], anc, axis=0),
            "cum_reward": jnp.take(p["cum_reward"], anc, axis=0),
            "seq": jnp.take(p["seq"], anc, axis=0),
        }

    def summary(p, w):
        return {"reward": jnp.sum(w * p["reward"].astype(w.dtype))}

    return SMCSpec(init, transition, loglik, gather=gather, summary=summary)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling (systematic resampling)")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterConfig, ParticleFilter
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    t0 = time.perf_counter()
    if args.smc:
        spec = make_smc_decode_spec(
            params, cfg, policy, decode,
            temperature=args.temperature, steps=args.steps,
        )
        # Engine resampling criterion: ESS < frac * n + 0.5 (the canonical
        # filter semantics; the pre-engine loop compared strictly).
        flt = ParticleFilter(
            spec,
            FilterConfig(policy=policy, ess_threshold=args.ess_frac),
        )
        n_resample = 0
        state = None
        for state, out in flt.stream(
            jax.random.key(args.seed), range(args.steps), b
        ):
            n_resample += int(out.resampled)
        seqs = np.asarray(state.particles["seq"])
    else:
        cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
        tok = jnp.zeros((b,), jnp.int32)
        seqs = np.zeros((b, args.steps), np.int32)
        key = jax.random.key(args.seed)
        n_resample = 0
        for i in range(args.steps):
            logits, cache = decode(params, tok, jnp.int32(i), cache)
            logits = logits.astype(jnp.float32)
            key, k1 = jax.random.split(key)
            if args.temperature > 0:
                tok = jax.random.categorical(
                    k1, logits / args.temperature, -1
                )
            else:
                tok = jnp.argmax(logits, -1)
            seqs[:, i] = np.asarray(tok)
    dt = time.perf_counter() - t0
    mode = "smc" if args.smc else "independent"
    print(f"arch={cfg.name} {mode} batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)"
          + (f" resamples={n_resample}" if args.smc else ""))
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _batch_axis(x, n):
    for i, d in enumerate(x.shape):
        if d == n:
            return i
    raise ValueError(f"no batch axis in {x.shape}")


if __name__ == "__main__":
    main()
