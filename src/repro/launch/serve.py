"""Batched serving driver: prefill queue -> continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --slots 4 --requests 8 \
        --particles 4:32 --mesh 2x2 --async-admit]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``).  The SMC path is the engine
API end to end: decoding is expressed as an ``SMCSpec`` (one particle =
one partial sequence, its cache the state; propagation = sample a token;
weight = model log-prob at T=1) and served by a ``FilterBank`` — one slot
per in-flight request, ``--particles-per-slot`` particles each — under a
continuous-batching scheduler: requests are admitted into free slots
mid-flight, retired on completion, and the bank steps every tick regardless
of occupancy (the scheduler never waits to fill the batch and never
recompiles; slot lifecycle is ``reset_slot`` by traced index).

Particle budgets are per request (``--particles MIN:MAX``): the bank is a
*ragged* FilterBank at lane width MAX, each request draws a key-derived
power-of-two size class in [MIN, MAX] and is admitted at that traced
active count (``reset_slot(..., n_active=n)`` — no recompile per size),
so easy requests stop paying for the hardest request's particle cloud.
The scheduler reports the padding this removes from the quality ledger
(``padding_waste``: active vs padded particle-ticks).  A single
``--particles N`` (or the legacy ``--particles-per-slot``) keeps the dense
bank and its mask-free fast path.

``--elastic`` makes those budgets *starting points*: an ESS-driven
``BudgetController`` (``repro.core.elastic``) grows a slot whose ESS
collapses and shrinks one whose ESS is comfortably high, via the bank's
traced ``resize_slot`` budget switch (no recompiles), optionally under a
global particle budget arbitrated by ESS deficit (``--elastic-budget``).
Per-tick decisions are reported, and all padding/particle-tick accounting
follows the *current* budgets rather than admission-time ones.

The bank composes with a device mesh (``--mesh DxM``): slots shard over
the "data" axis and each slot's particles over "model" (the engine's
mesh × bank composition — ``repro.core.distributed.make_dist_bank_step``),
so the same scheduler serves a multi-device bank unchanged: admissions
place their traced-index reset onto the owning shard, and every retire
reads back only that request's slot.  ``--async-admit`` switches the
scheduler to the double-buffered path: the next bank step is dispatched
*before* the host blocks on the previous tick's counters, so admit/retire
bookkeeping (and the host↔device slot swaps it triggers) overlap device
compute instead of serializing with it — identical schedules, one step of
read-back lag.

``--health`` arms the fault-tolerance layer: per-slot health sentinels
(``repro.core.health``) derived from the stats the scheduler already
reads back, host-side rollback snapshots, and an escalation ladder
(rollback → reseed → ``--fp32-fallback`` precision migration →
retire-with-error).  ``--chaos`` injects a deterministic, seed-derived
fault schedule (``repro.core.faults``) to exercise every rung on demand.
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_smc_decode_spec(
    params, cfg, policy, decode, *, temperature: float, steps: int,
    prompt_len: int = 0,
):
    """SMC decoding as a particle-filter model.

    Particle state: token, KV/recurrent cache, last reward, token history.
    The transition runs one batched decode step and samples at the
    exploration temperature; the likelihood is the reward recorded by the
    transition (the model's own T=1 log-prob of the sampled token).
    ``gather`` selects ancestors along each cache leaf's *known* particle
    axis — derived once from the cache layout, never guessed from shapes
    (a dimension that merely equals the particle count, e.g. a layer or
    head count, must not be mistaken for the batch axis); ``summary`` keeps
    the per-step estimate to one scalar (mean reward) instead of averaging
    whole caches.  ``steps`` sizes the cache/history buffers — the *maximum*
    request length a serving slot can hold.

    ``prompt_len`` reserves room for a prompt processed by a *batched
    prefill pass* (:class:`PrefillRunner`) before admission: the cache is
    sized for prompt + decode, and decode positions are offset by
    ``prompt_len - 1`` so the first decode step lands right after the
    prefill writes (positions ``0..prompt_len-2``) with the last prompt
    token as the slot's starting ``tok``.  ``prompt_len=0`` (default) is
    exactly the promptless spec.
    """
    from repro.core.filter import SMCSpec
    from repro.models import model as M

    s_max = steps + prompt_len + 1
    # Per-leaf particle axis: the one dimension whose extent follows the
    # batch argument of the cache layout (shape-only — nothing allocated).
    cache_axes = jax.tree.map(
        lambda a, b: _changed_axis(a.shape, b.shape),
        M.cache_specs(cfg, 2, s_max),
        M.cache_specs(cfg, 3, s_max),
        is_leaf=_is_param_spec,
    )

    def init(key, n):
        del key
        return {
            "tok": jnp.zeros((n,), jnp.int32),
            "cache": M.init_cache(cfg, n, s_max, policy.compute_dtype),
            "reward": jnp.zeros((n,), jnp.float32),
            # Lineage log-prob: cumulative reward along the surviving
            # ancestry (travels through resampling gathers), since the
            # engine renormalizes the filter weights every step.
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        pos = step.astype(jnp.int32)
        if prompt_len:
            pos = pos + jnp.int32(prompt_len - 1)
        logits, cache = decode(params, p["tok"], pos, p["cache"])
        logits = logits.astype(jnp.float32)
        if temperature > 0:
            tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        logp = jax.nn.log_softmax(logits, -1)
        reward = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        # Freed slots keep ticking past their request's budget ("the bank
        # steps every tick"); clamp the history index so their writes land
        # on the last column instead of out of bounds.
        pos = jnp.minimum(step, steps - 1)
        return {
            "tok": tok,
            "cache": cache,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    def gather(p, anc):
        return {
            "tok": jnp.take(p["tok"], anc, axis=0),
            "cache": jax.tree.map(
                lambda x, ax: jnp.take(x, anc, axis=ax),
                p["cache"],
                cache_axes,
            ),
            "reward": jnp.take(p["reward"], anc, axis=0),
            "cum_reward": jnp.take(p["cum_reward"], anc, axis=0),
            "seq": jnp.take(p["seq"], anc, axis=0),
        }

    def summary(p, w):
        return {"reward": jnp.sum(w * p["reward"].astype(w.dtype))}

    return SMCSpec(
        init,
        transition,
        loglik,
        gather=gather,
        summary=summary,
        particle_axes={
            "tok": 0,
            "cache": cache_axes,
            "reward": 0,
            "cum_reward": 0,
            "seq": 0,
        },
    )


def _request_budgets(
    key: jax.Array, num_requests: int, min_steps: int, max_steps: int
) -> np.ndarray:
    """Per-request decode budgets in [min_steps, max_steps], keyed.

    The whole workload derives from the scheduler key — two seeds draw two
    schedules, one seed reproduces (the old hardcoded
    ``np.random.default_rng(0)`` made every ``--seed`` serve the same
    traffic).
    """
    return np.array(
        jax.random.randint(key, (num_requests,), min_steps, max_steps + 1)
    )


def particle_size_classes(p_min: int, p_max: int) -> list[int]:
    """Power-of-two ladder of particle budgets from p_min up to p_max.

    Requests are binned into these classes rather than arbitrary counts so
    a packer can group same-class requests (and so budgets stay friendly to
    lane-width-128 kernels): [p_min, 2*p_min, 4*p_min, ..., p_max].
    """
    if not 1 <= p_min <= p_max:
        raise ValueError(
            f"need 1 <= min <= max particle budgets, got {p_min}:{p_max}"
        )
    classes = []
    c = p_min
    while c < p_max:
        classes.append(c)
        c *= 2
    classes.append(p_max)
    return classes


def _request_particles(
    key: jax.Array, num_requests: int, p_min: int, p_max: int
) -> np.ndarray:
    """Key-derived per-request particle budgets drawn from the size-class
    ladder — the heterogeneous-difficulty workload of a ragged bank."""
    classes = np.asarray(particle_size_classes(p_min, p_max))
    idx = np.asarray(
        jax.random.randint(key, (num_requests,), 0, len(classes))
    )
    return classes[idx]


def make_packed_banks(spec, config, *, num_slots: int, p_min: int, p_max: int):
    """One width-matched FilterBank per particle size class.

    The multi-bank packing engine's bank family: ``num_slots`` total slots
    spread over the power-of-two ladder :func:`particle_size_classes`
    (remainder slots go to the *widest* classes — they double as the
    spillover and migration targets).  Banks after the first are built via
    :meth:`FilterBank.sibling`, so the family shares one set of jitted
    entry points — N class banks never N× compile.

    Returns ``{class_width: FilterBank}``, the packed-mode ``bank``
    argument of :func:`run_continuous_batching`.
    """
    from repro.core import FilterBank

    classes = particle_size_classes(p_min, p_max)
    if num_slots < len(classes):
        raise ValueError(
            f"packed banks need at least one slot per size class: "
            f"{num_slots} slots < {len(classes)} classes "
            f"{classes} (raise --slots or narrow --particles)"
        )
    base, rem = divmod(num_slots, len(classes))
    counts = {c: base for c in classes}
    for c in classes[len(classes) - rem:]:
        counts[c] += 1
    banks, donor = {}, None
    for c in classes:
        if donor is None:
            banks[c] = donor = FilterBank(spec, config, num_slots=counts[c])
        else:
            banks[c] = donor.sibling(num_slots=counts[c])
    return banks


class _Lane:
    """One width-matched FilterBank plus its scheduler-side mirrors.

    ``offset`` maps the lane's local slots into the packed scheduler's
    *global* slot space (``global = offset + slot``) — the index space the
    shared :class:`~repro.core.elastic.BudgetController`, the budget
    mirror, and every reported event use.
    """

    def __init__(self, bank, width: int, index: int, offset: int,
                 ragged: bool):
        self.bank = bank
        self.width = width
        self.index = index
        self.offset = offset
        self.ragged = ragged
        self.nb = bank.num_slots
        self.free = list(range(self.nb))[::-1]
        self.active: dict[int, dict] = {}
        self.obs = jnp.zeros((self.nb,), jnp.int32)
        self.state = None
        self.tick_ms: list[float] = []
        # (dispatch_time, FilterOutput) of the most recent step whose
        # read-backs have not been consumed yet (async latency tracking).
        self.prev = None

    def init_state(self, k_state: jax.Array):
        k = (
            k_state
            if self.index == 0
            else jax.random.fold_in(k_state, self.index)
        )
        if self.ragged:
            # Ragged states must be ragged from init (the pytree cannot
            # grow a count field under jit); empty slots idle at full
            # width.
            self.state = self.bank.init(
                k,
                self.width,
                n_active=jnp.full((self.nb,), self.width, jnp.int32),
            )
        else:
            self.state = self.bank.init(k, self.width)

    def step_keys(self, k_run: jax.Array, tick: int) -> jax.Array:
        base = jax.random.fold_in(k_run, tick)
        if self.index:
            base = jax.random.fold_in(base, self.index)
        return jax.random.split(base, self.nb)


class SizeClassPacker:
    """Width-ordered first-fit routing with work-conserving spillover.

    A request routes to the narrowest lane whose width fits its particle
    budget; when that home lane is full it may be *promoted* to the next
    wider lane with a free slot (admitted at its true budget — the extra
    lanes are charged as padding).  Scanning arrived requests in FIFO
    order with first-fit placement gives the work-conserving invariant:
    after an admission pass, no arrived request is still queued while any
    lane wide enough for it has a free slot — no bank idles while another
    queues.  A later small request may pass a blocked larger one (no
    head-of-line blocking), but never displaces a placeable one, and the
    scan order is deterministic: one seed, one schedule.
    """

    def __init__(self, lanes):
        self.lanes = sorted(lanes, key=lambda ln: ln.width)

    def place(self, budget: int):
        """The lane this request is admitted into, or None (all full)."""
        for lane in self.lanes:
            if lane.width >= budget and lane.free:
                return lane
        return None


class PrefillRunner:
    """Batched prompt prefill for SMC decode requests.

    The continuous-batching prefill/decode split: instead of each request
    burning its first in-slot ticks decoding the prompt token by token,
    every request admitted on a tick has its prompt processed as **one
    batched prefill pass** (positions ``0..prompt_len-2`` through the same
    jitted decode step the spec's transition uses, over a fixed-width
    request batch), and the resulting cache row is broadcast over the
    slot's particle lanes and uploaded via
    ``FilterBank.init_slot(particles=...)``.  The decode loop then starts
    at the last prompt token with a warm cache; the spec's position offset
    (``prompt_len - 1`` — see :func:`make_smc_decode_spec`) keeps decode
    writes contiguous with the prefill's.

    Prompts are key-derived per request (:meth:`make_prompts`, called by
    the scheduler from its workload key) — the serving analogue of the
    key-derived budgets.  The pass compiles once for the fixed
    ``(batch, prompt_len)`` block; short admission ticks pad the block by
    repeating the last request (wasted lanes, never wasted compiles).
    """

    def __init__(self, params, cfg, policy, decode, *,
                 prompt_len: int, steps: int, batch: int):
        from repro.models import model as M

        if prompt_len < 1:
            raise ValueError(
                f"prompt_len must be >= 1 (0 disables prefill), got "
                f"{prompt_len}"
            )
        if batch < 1:
            raise ValueError(f"prefill batch must be >= 1, got {batch}")
        self._M = M
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.decode = decode
        self.prompt_len = prompt_len
        self.steps = steps
        self.s_max = steps + prompt_len + 1
        self.batch = batch
        self.batches = 0
        self.prompts = None
        self.cache_axes = jax.tree.map(
            lambda a, b: _changed_axis(a.shape, b.shape),
            M.cache_specs(cfg, 2, self.s_max),
            M.cache_specs(cfg, 3, self.s_max),
            is_leaf=_is_param_spec,
        )
        self._pass = jax.jit(self._prefill_block)
        self._builders: dict[int, object] = {}

    def make_prompts(self, key: jax.Array, num_requests: int) -> None:
        """Key-derived (num_requests, prompt_len) token prompts."""
        self.prompts = jax.random.randint(
            key,
            (num_requests, self.prompt_len),
            0,
            self.cfg.vocab_size,
            dtype=jnp.int32,
        )

    def _prefill_block(self, block):
        """One batched prompt pass: (batch, L) tokens -> filled caches."""
        cache = self._M.init_cache(
            self.cfg, block.shape[0], self.s_max, self.policy.compute_dtype
        )
        if self.prompt_len == 1:
            return cache  # nothing precedes the starting token

        def body(cache, xs):
            tok, pos = xs
            _, cache = self.decode(self.params, tok, pos, cache)
            return cache, None

        cache, _ = jax.lax.scan(
            body,
            cache,
            (
                block[:, :-1].T,
                jnp.arange(self.prompt_len - 1, dtype=jnp.int32),
            ),
        )
        return cache

    def _rows_builder(self, width: int):
        """Jitted (cache_block, batch_index, tok) -> slot particle rows;
        the batch index stays traced, so one compile per lane width."""
        fn = self._builders.get(width)
        if fn is None:
            axes = self.cache_axes
            steps = self.steps

            def build(cache_block, r, tok):
                row_cache = jax.tree.map(
                    lambda x, ax: jnp.broadcast_to(
                        jax.lax.dynamic_index_in_dim(
                            x, r, axis=ax, keepdims=True
                        ),
                        x.shape[:ax] + (width,) + x.shape[ax + 1:],
                    ),
                    cache_block,
                    axes,
                )
                return {
                    "tok": jnp.full((width,), tok, jnp.int32),
                    "cache": row_cache,
                    "reward": jnp.zeros((width,), jnp.float32),
                    "cum_reward": jnp.zeros((width,), jnp.float32),
                    "seq": jnp.zeros((width, steps), jnp.int32),
                }

            fn = self._builders[width] = jax.jit(build)
        return fn

    def rows_for(self, ids: list[int], widths: list[int]) -> list:
        """Slot upload rows for one tick's admissions, batch-prefilled."""
        if self.prompts is None:
            raise ValueError(
                "PrefillRunner.make_prompts was never called (the "
                "scheduler derives prompts from its workload key)"
            )
        out = []
        for lo in range(0, len(ids), self.batch):
            chunk = ids[lo:lo + self.batch]
            pad = chunk + [chunk[-1]] * (self.batch - len(chunk))
            block = jnp.take(
                self.prompts, jnp.asarray(pad, jnp.int32), axis=0
            )
            cache_block = self._pass(block)
            self.batches += 1
            for j, rid in enumerate(chunk):
                out.append(
                    self._rows_builder(widths[lo + j])(
                        cache_block, jnp.int32(j), self.prompts[rid, -1]
                    )
                )
        return out


def _latency_summary(lanes, tick_deadline_ms):
    """p50/p95/max step wall-times per lane and pooled, plus the
    over-deadline count the future SLO-aware arbiter will consume."""
    per_bank, pooled = {}, []

    def _summ(ms):
        arr = np.asarray(ms, np.float64)
        over = (
            int((arr > tick_deadline_ms).sum())
            if tick_deadline_ms is not None
            else 0
        )
        return {
            "ticks": int(arr.size),
            "p50_ms": float(np.percentile(arr, 50)) if arr.size else 0.0,
            "p95_ms": float(np.percentile(arr, 95)) if arr.size else 0.0,
            "max_ms": float(arr.max()) if arr.size else 0.0,
            "ticks_over_deadline": over,
        }

    for lane in lanes:
        per_bank[lane.width] = _summ(lane.tick_ms)
        pooled.extend(lane.tick_ms)
    return {
        **_summ(pooled),
        "deadline_ms": tick_deadline_ms,
        "per_bank": per_bank,
    }


def run_continuous_batching(
    bank,
    *,
    num_requests: int,
    max_steps: int,
    particles: int | tuple[int, int],
    key: jax.Array,
    arrival_every: int = 1,
    min_steps: int | None = None,
    async_admit: bool = False,
    elastic=None,
    prefill=None,
    pipelined_uploads: bool = False,
    tick_deadline_ms: float | None = None,
    health=None,
    chaos=None,
    fallback_bank=None,
) -> dict:
    """Admit → step → retire loop over a FilterBank of decode slots.

    Requests arrive on a fixed schedule (request ``i`` at tick
    ``i * arrival_every``) with key-derived budgets in
    [min_steps, max_steps].  A free slot is claimed by ``reset_slot``
    (traced slot index — no recompile); the whole bank steps every tick
    whether or not every slot holds a request; a slot retires the moment
    its step counter reaches its request's budget, returning the
    highest-cumulative-reward particle's sequence.  Works unchanged over a
    mesh-sharded bank (``FilterConfig(mesh=...)``): resets land on the
    owning shard, retires read back per-slot rows.

    ``particles`` may be a single count (dense bank, every request at the
    same width) or a ``(min, max)`` range — the *ragged* bank: the bank is
    built at lane width ``max``, every request draws a key-derived particle
    budget from the power-of-two size-class ladder in [min, max]
    (:func:`particle_size_classes`), and admission resets the slot at that
    *traced* count (``reset_slot(..., n_active=n)`` — no recompile per
    size).  Easy requests then carry e.g. 256 active lanes while hard ones
    carry 4096 in the same bank, and the returned stats report the padding
    this saves from the quality ledger: ``active_particle_ticks`` (lanes
    that did useful work), ``padded_particle_ticks`` (lanes a pad-to-max
    bank would bill), and ``padding_waste`` (their gap as a fraction).

    ``async_admit`` double-buffers the loop: each tick's bank step is
    dispatched *before* the host blocks on the previous tick's counters,
    so retire/extract bookkeeping overlaps device compute (the retiring
    slot's data is read from the already-materialized pre-step state — the
    in-flight step never gates a host decision).  While free slots exist
    the schedule matches the synchronous path tick for tick; when a
    request queues for a freed slot its admission lags by one tick — the
    price of never stalling the device on a host decision.  Returns
    per-request results plus occupancy/latency stats.

    ``elastic`` (an :class:`repro.core.elastic.ElasticConfig`, ragged banks
    only) turns admission budgets into *starting* budgets: a
    ``BudgetController`` watches each busy slot's per-step ESS (free from
    the fused epilogue's stats) and rewrites its active count in flight via
    ``bank.resize_slot`` — grow on ESS collapse, shrink when healthy, with
    deadband + cooldown and an optional global particle budget arbitrated
    by ESS deficit.  Synchronous ticks resize from the tick's own ESS;
    async ticks apply the *previous* tick's already-materialized ESS so
    the in-flight step is never waited on (one tick of controller lag, the
    same lag the scheduler already accepts for retires).  All particle-tick
    accounting (``active``/``padded``/``padding_waste``) and the retire
    read of the best particle use the slot's *current* budget from a host
    mirror updated at admit and at every granted resize, so the ledger
    stays truthful as budgets move mid-flight.  Decisions are returned in
    ``stats["elastic"]`` (per-event tick/slot/kind/ess/deficit plus
    grow/shrink/denied counters).

    **Packed mode** — pass ``bank`` as a ``{class_width: FilterBank}``
    family (:func:`make_packed_banks`) instead of one bank, and the
    scheduler becomes a multi-bank packing engine: each request routes to
    the narrowest bank whose lane width fits its particle budget
    (:class:`SizeClassPacker`), so an easy 256-particle request never
    pays a 4096-wide bank's lanes just because a hard request exists.
    Spillover is work-conserving: a request whose home class is full is
    promoted to the next wider bank with a free slot (admitted at its
    true budget, the extra lanes charged as padding in
    ``stats["packed"]["spillover_admissions"]``) rather than queueing
    while capacity idles.  Elastic resizes that cross a class boundary
    *migrate* the slot (``export_slot`` → masked-resample
    ``import_slot`` draw into the width-matched bank); a grow with no
    wide-enough free slot is reclassified as denied and backs off.
    Migration/spillover counts and per-class particle-tick ledgers land
    in ``stats["packed"]``.

    ``prefill`` (a :class:`PrefillRunner`) splits serving into
    prefill/decode: each tick's admissions run their prompts as one
    batched prefill pass and enter their slots with warm caches, so the
    decode loop never spends bank ticks re-reading prompts.
    ``pipelined_uploads`` (async mode only) moves admission/migration
    uploads to the tail of the tick, after the next step is already
    dispatch-eligible: slot-state uploads are enqueued against the
    in-flight step's output and overlap it, exactly like read-backs
    already do — the schedule (and every result) is bitwise identical to
    plain ``async_admit``; only the host timeline changes.

    Every tick's step wall-time is recorded per bank (dispatch→ready in
    sync mode, dispatch→consumption in async mode — the serving-relevant
    "how stale was this tick's data" number) and summarized in
    ``stats["latency"]`` as p50/p95/max per bank and pooled, plus
    ``ticks_over_deadline`` against ``tick_deadline_ms`` when given.
    With ``elastic`` active, ``tick_deadline_ms`` is also an SLO input:
    a grow whose lane's recent p95 step time already exceeds the
    deadline is denied (``reason="latency"``, counted separately from
    budget denials) — more lanes on an already-late bank only makes the
    tail worse.

    **Fault tolerance** — pass ``health`` (a
    :class:`repro.core.health.HealthConfig`) and the scheduler watches
    every busy slot with zero extra device passes (the per-slot health
    rules are all derived from the ESS / evidence / step-counter numbers
    it already reads back each tick) plus a wall-clock step watchdog.  A
    tripped slot never retires as a success; instead the scheduler walks
    an escalation ladder, one rung per validated read: **rollback** to
    the newest host-side ring-buffer snapshot (``export_slot`` copies
    taken every ``health.snapshot_every`` ticks), then **reseed** from
    the prior, then **precision fallback** — migrate the slot into
    ``fallback_bank`` (an fp32-policy bank joined to the family as an
    extra lane that packer and elastic never route to), then
    **retire-with-error** (the result carries ``"error"`` instead of
    tokens — containment, not silence).  ``stuck`` trips (a dropped slot
    upload: scheduler bookkeeping without the device write) re-upload
    from the request's own admission key.  Failed step dispatches retry
    with bounded backoff on the non-donated entry point.  Counters,
    per-incident recovery latencies, and snapshot/ladder totals land in
    ``stats["health"]``.

    ``chaos`` (a :class:`repro.core.faults.ChaosConfig`, needs
    ``health``) arms the deterministic fault injector: the whole fault
    schedule derives from the run key, so one seed is one exact chaos
    scenario.  The applied-fault log lands in ``stats["chaos"]``.  With
    ``health=None`` and ``chaos=None`` every hook in this function is
    dead (``if monitor/injector is not None``): the no-fault serve path
    is bitwise identical to a build without the fault-tolerance layer.
    """
    if min_steps is None:
        min_steps = max(1, max_steps // 2)
    if not 0 <= min_steps <= max_steps:
        raise ValueError(
            f"need 0 <= min_steps <= max_steps, got min_steps={min_steps}, "
            f"max_steps={max_steps}"
        )
    if isinstance(particles, tuple):
        p_min, p_max = particles
    else:
        p_min = p_max = particles
    ragged = p_min < p_max
    packed = isinstance(bank, dict)
    if packed:
        widths = sorted(bank)
        expect = particle_size_classes(p_min, p_max)
        if widths != expect:
            raise ValueError(
                f"packed bank widths {widths} do not match the size-class "
                f"ladder {expect} for particles=({p_min}, {p_max}) — build "
                f"them with make_packed_banks"
            )
    if pipelined_uploads and not async_admit:
        raise ValueError(
            "pipelined_uploads overlaps uploads with the in-flight step — "
            "it requires async_admit=True"
        )
    # Every lane in a multi-class family is ragged: spillover and
    # migration put narrower-budget requests into wider banks, so even
    # the widest class needs runtime counts.  A single-class family is a
    # plain dense bank wearing the packed API — kept dense so it stays
    # bitwise identical to the single-bank path.
    packed_multi = packed and len(bank) > 1
    lanes: list[_Lane] = []
    if packed:
        offset = 0
        for i, w in enumerate(sorted(bank)):
            lane = _Lane(bank[w], w, i, offset, ragged=packed_multi or ragged)
            lanes.append(lane)
            offset += lane.nb
    else:
        lanes.append(_Lane(bank, p_max, 0, 0, ragged=ragged))
    packer = SizeClassPacker(lanes)
    fb_lane = None
    if fallback_bank is not None:
        if health is None:
            raise ValueError(
                "fallback_bank is the precision-fallback rung of the "
                "health escalation ladder: pass health=HealthConfig(...)"
            )
        # The fallback lane joins the family *after* the packer is built:
        # it steps, retires, and reports latency like any lane, but no
        # admission, spillover, or elastic migration ever routes to it —
        # only the ladder's precision-fallback rung moves slots in.
        fb_lane = _Lane(
            fallback_bank,
            p_max,
            len(lanes),
            sum(lane.nb for lane in lanes),
            ragged=True,
        )
        lanes.append(fb_lane)
    total_slots = sum(lane.nb for lane in lanes)
    ctrl = None
    if elastic is not None:
        from repro.core.elastic import BudgetController

        if not (ragged or packed_multi):
            raise ValueError(
                "elastic budgets need a ragged bank: pass "
                "particles=(MIN, MAX) with MIN < MAX so per-slot counts "
                "are runtime values"
            )
        if elastic.max_particles > p_max:
            raise ValueError(
                f"elastic.max_particles={elastic.max_particles} exceeds "
                f"the bank's lane width {p_max}"
            )
        ctrl = BudgetController(elastic, total_slots)
    monitor = ring = injector = k_health = None
    if health is not None:
        import dataclasses

        from repro.checkpoint import SlotSnapshotRing
        from repro.core.health import HealthMonitor

        if ctrl is not None and health.collapse_below > 0:
            # The elastic controller already owns the collapse signal
            # (grow → reseed escalation); two loops acting on the same
            # ESS would fight over the slot.
            health = dataclasses.replace(health, collapse_below=0.0)
        monitor = HealthMonitor(health, total_slots)
        ring = SlotSnapshotRing(depth=health.snapshot_depth)
        # The ladder's own key stream: fold_in, NOT a wider split — the
        # split(5) below must stay byte-identical so health-on runs
        # reproduce health-off schedules bit for bit.
        k_health = jax.random.fold_in(key, 0x8EA17)
    if chaos is not None:
        from repro.core.faults import FaultInjector

        if monitor is None:
            raise ValueError(
                "chaos injection without health monitoring would just "
                "corrupt results: pass health=HealthConfig(...)"
            )
        injector = FaultInjector(
            chaos,
            jax.random.fold_in(key, 0xC4A05),
            num_slots=total_slots,
            num_lanes=len(lanes),
        )
    k_state, k_admit, k_run, k_sched, k_elastic = jax.random.split(key, 5)
    lengths = _request_budgets(k_sched, num_requests, min_steps, max_steps)
    if ragged:
        budgets = _request_particles(
            jax.random.fold_in(k_sched, 1), num_requests, p_min, p_max
        )
    else:
        budgets = np.full((num_requests,), p_max)
    if prefill is not None:
        prefill.make_prompts(jax.random.fold_in(k_sched, 2), num_requests)
    pending = collections.deque(
        {
            "id": i,
            "steps": int(lengths[i]),
            "particles": int(budgets[i]),
            "arrival": i * arrival_every,
        }
        for i in range(num_requests)
    )
    for lane in lanes:
        lane.init_state(k_state)
        # Synchronous ticks donate the bank state: step and admission
        # reuse the particle/weight/cache buffers in place instead of
        # copying them every tick (the pre-step state is never read after
        # the call).  The async path must NOT donate its step input —
        # retire reads the *pre-step* state while the step runs on
        # device, so aliasing those buffers would hand retire reclaimed
        # memory.  Chaos mode must not either: a failed dispatch retries
        # from the pre-step state, which donation would have reclaimed.
        lane.step_fn = (
            lane.bank.jit_step
            if (async_admit or injector is not None)
            else lane.bank.jit_step_donated
        )

    # Global slot space: lane i's slot s is global slot lane.offset + s.
    # The controller, the budget mirror, and every event speak global ids.
    lane_of: list[_Lane] = []
    for lane in lanes:
        lane_of.extend([lane] * lane.nb)
    lane_width_vec = np.asarray([lane.width for lane in lane_of], np.int64)
    results, tick, busy_slot_ticks = [], 0, 0
    active_particle_ticks, padded_particle_ticks = 0, 0
    # Host mirror of each slot's *current* particle budget.  Admission
    # seeds it; every granted elastic resize updates it; all particle-tick
    # accounting and retire reads go through it instead of the
    # admission-time ``req["particles"]`` (stale once budgets move).
    slot_budget = np.zeros(total_slots, np.int64)
    # Progress-integrity base per slot: the device step counter must read
    # ``base_step + (examine_tick - base_tick)`` for every busy slot.
    # Admission sets (0, admitted_tick); rollback/re-upload reset it to
    # the restored step.  Any mismatch is a "stuck" health trip — how a
    # dropped upload surfaces without a dedicated probe.
    step_base = np.zeros(total_slots, np.int64)
    step_base_tick = np.zeros(total_slots, np.int64)
    # Actions applied after a surgery land one examined read later in
    # sync mode, two in async (the extra tick of read-back lag) — the
    # ladder escalates only after a rung has had one validated read.
    obs_lag = 2 if async_admit else 1
    hstats = {
        "ladder_keys": 0,
        "rollbacks": 0,
        "reseeds": 0,
        "reuploads": 0,
        "fallback_migrations": 0,
        "retired_error": 0,
        "lane_failures": 0,
    }
    events: list[dict] = []
    packed_stats = {
        "spillover_admissions": 0,
        "migrations": 0,
        "migrations_blocked": 0,
        "lane_particle_ticks": 0,
    }

    def admit_all(tick):
        """One admission pass: route every arrived request via the packer.

        FIFO over arrivals with first-fit placement — work-conserving: a
        request only stays queued if *no* wide-enough lane has a free
        slot, and a blocked large request never blocks a placeable small
        one behind it (unplaceable requests are deferred back to the
        queue head in order).  With prefill active, the tick's admissions
        run their prompts as one batched pass before any slot upload.
        """
        arrived = []
        while pending and pending[0]["arrival"] <= tick:
            arrived.append(pending.popleft())
        placed, deferred = [], []
        for req in arrived:
            lane = packer.place(req["particles"])
            if lane is None:
                deferred.append(req)
                continue
            placed.append((req, lane, lane.free.pop()))
        for req in reversed(deferred):
            pending.appendleft(req)
        rows = None
        if prefill is not None and placed:
            rows = prefill.rows_for(
                [req["id"] for req, _, _ in placed],
                [lane.width for _, lane, _ in placed],
            )
        for j, (req, lane, slot) in enumerate(placed):
            k = jax.random.fold_in(k_admit, req["id"])
            dropped = None
            if injector is not None:
                dropped = injector.take_drop_upload(tick)
                if dropped is not None:
                    injector.applied(dropped, tick, lane.offset + slot)
            if dropped is not None:
                # Swallowed upload: the scheduler's bookkeeping proceeds
                # while the device keeps the previous occupant's state —
                # the stuck-step integrity rule must catch this.
                pass
            elif lane.ragged and rows is not None:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state,
                    jnp.int32(slot),
                    k,
                    jnp.int32(req["particles"]),
                    rows[j],
                )
            elif lane.ragged:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state,
                    jnp.int32(slot),
                    k,
                    jnp.int32(req["particles"]),
                )
            elif rows is not None:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state, jnp.int32(slot), k, None, rows[j]
                )
            else:
                lane.state = lane.bank.jit_init_slot_donated(
                    lane.state, jnp.int32(slot), k
                )
            req["admitted_tick"] = tick
            lane.active[slot] = req
            g = lane.offset + slot
            slot_budget[g] = req["particles"]
            if monitor is not None:
                # This slot's history belongs to a dead request.
                monitor.slot_reset(g)
                ring.clear(g)
                step_base[g] = 0
                step_base_tick[g] = tick
            if packed_multi and lane.width > req["particles"]:
                # Promoted past its home class: admitted at its true
                # budget, the lane's extra width is charged as padding.
                packed_stats["spillover_admissions"] += 1
            if ctrl is not None:
                # Grace period: a fresh request's first ESS readings are
                # noise; hold resizes for one full cooldown window.
                ctrl.slot_admitted(g)

    def retire(lane, ex_state, ex_tick):
        """Retire against a state holding ``ex_tick`` completed steps."""
        if not lane.active:
            return
        steps_now = np.asarray(ex_state.step)
        done = [
            s
            for s in lane.active
            if lane.active[s]["admitted_tick"] < ex_tick
            and steps_now[s] >= lane.active[s]["steps"]
            # A slot mid-incident never retires as a success: the ladder
            # either recovers it (it retires on a later healthy read) or
            # retires it with an error itself.
            and (monitor is None or monitor.pending(lane.offset + s) is None)
        ]
        if not done:
            return
        cum = np.asarray(ex_state.particles["cum_reward"], np.float32)
        seqs = np.asarray(ex_state.particles["seq"])
        for slot in done:
            # Best particle over the slot's *currently active* lanes only —
            # lanes beyond the current budget hold junk (a shrunk slot's
            # old lanes included) that must never win the argmax.
            n_now = int(slot_budget[lane.offset + slot])
            best = int(np.argmax(cum[slot, :n_now]))
            if monitor is not None and not np.isfinite(cum[slot, best]):
                # Last line of defense: corrupted state that slipped past
                # every per-tick rule must still never reach a success
                # summary (NaN wins np.argmax, so poison lands here).
                retire_error(lane, slot, "nonfinite_at_retire", ex_tick)
                continue
            req = lane.active.pop(slot)
            res = {
                "id": req["id"],
                "steps": req["steps"],
                "particles": req["particles"],
                "final_particles": n_now,
                # A real copy, not a view: np.asarray above is
                # zero-copy into the jax buffer, and a live external
                # view would block the donated step/reset from
                # aliasing the bank state on every later tick (and
                # pin the whole (nb, P, steps) seq array per retired
                # request until the run ends).
                "tokens": np.array(seqs[slot, best, : req["steps"]]),
                "admitted_tick": req["admitted_tick"],
                "finished_tick": ex_tick,
            }
            if packed:
                res["lane_width"] = lane.width
            results.append(res)
            lane.free.append(slot)
            if monitor is not None:
                monitor.slot_reset(lane.offset + slot)
                ring.clear(lane.offset + slot)

    def retire_error(lane, slot, reason, tick):
        """Terminal containment: the request leaves with an ``error``
        result instead of tokens — never a junk sequence, never a hung
        slot.  The slot is freed for the next request (its state is
        overwritten by the admission upload)."""
        g = lane.offset + slot
        req = lane.active.pop(slot)
        res = {
            "id": req["id"],
            "steps": req["steps"],
            "particles": req["particles"],
            "final_particles": int(slot_budget[g]),
            "tokens": np.zeros(0, np.int32),
            "admitted_tick": req["admitted_tick"],
            "finished_tick": tick,
            "error": reason,
        }
        if packed:
            res["lane_width"] = lane.width
        results.append(res)
        lane.free.append(slot)
        slot_budget[g] = 0
        ring.clear(g)
        monitor.slot_failed(g, tick, "retire_error")
        hstats["retired_error"] += 1

    def migrate(src, slot, dst, d, k, ev):
        """Move one live slot across banks: export → width-matched import.

        The export is a non-destructive read of the source's current
        (post-step) state; its outputs are fresh buffers, so the donated
        import and every later donated op on the source state stay safe.
        """
        rows, lw_row, step_row = src.bank.jit_export_slot(
            src.state, jnp.int32(slot)
        )
        dslot = dst.free.pop()
        dst.state = dst.bank.jit_import_slot_donated(
            dst.state,
            jnp.int32(dslot),
            rows,
            lw_row,
            k,
            jnp.int32(d.new),
            step_row,
        )
        req = src.active.pop(slot)
        dst.active[dslot] = req
        src.free.append(slot)
        g_src, g_dst = src.offset + slot, dst.offset + dslot
        # Cooldown/collapse history travels with the request.
        ctrl.slot_moved(g_src, g_dst)
        slot_budget[g_dst] = d.new
        slot_budget[g_src] = 0
        if monitor is not None:
            # Health history, snapshots, and the step-integrity base
            # follow the request across banks.
            monitor.slot_moved(g_src, g_dst)
            ring.move(g_src, g_dst)
            step_base[g_dst] = step_base[g_src]
            step_base_tick[g_dst] = step_base_tick[g_src]
        packed_stats["migrations"] += 1
        ev["migrated_to"] = g_dst
        ev["from_width"] = src.width
        ev["to_width"] = dst.width

    def apply_elastic(ess, tick):
        """Run one controller tick and apply granted decisions in place.

        Each lane's ``state`` here is its freshest (post-step) state and
        nothing else reads it afterward, so the donated resize / reseed /
        import are safe.  Decisions whose new count crosses a class
        boundary migrate (grow: ``migrate=True`` from the controller;
        shrink: repacked downward whenever a narrower class has room);
        everything else resizes in place.
        """
        busy_mask = np.zeros(total_slots, bool)
        for lane in lanes:
            if lane is fb_lane:
                continue  # fallback slots traded autoscaling for stability
            for s in lane.active:
                g = lane.offset + s
                if monitor is not None and monitor.pending(g) is not None:
                    continue  # mid-incident: the health ladder owns it
                busy_mask[g] = True
        lane_p95 = None
        if tick_deadline_ms is not None:
            # Per-slot p95 of its lane's recent step wall-times: the
            # SLO-aware grow-denial signal (a grow on an already-late
            # bank only worsens the tail).
            lane_p95 = np.zeros(total_slots, np.float64)
            for lane in lanes:
                if lane.tick_ms:
                    lane_p95[lane.offset:lane.offset + lane.nb] = (
                        np.percentile(
                            np.asarray(lane.tick_ms[-16:], np.float64), 95
                        )
                    )
        decisions = ctrl.observe(
            ess,
            slot_budget,
            busy_mask,
            lane_width=lane_width_vec if packed_multi else None,
            lane_p95_ms=lane_p95,
            deadline_ms=tick_deadline_ms,
        )
        for d in decisions:
            ev = {
                "tick": tick,
                "slot": d.slot,
                "old": d.old,
                "new": d.new,
                "ess": d.ess,
                "kind": d.kind,
                "granted": d.granted,
                "deficit": d.deficit,
            }
            if d.reason:
                ev["reason"] = d.reason
            events.append(ev)
            if not d.granted:
                continue
            k = jax.random.fold_in(k_elastic, len(events))
            lane = lane_of[d.slot]
            slot = d.slot - lane.offset
            if d.kind == "reseed":
                # Collapse recovery: fresh diffuse cloud at the slot's
                # max budget, progress kept — a restart, not a retire.
                lane.state = lane.bank.jit_reseed_slot_donated(
                    lane.state, jnp.int32(slot), k, jnp.int32(d.new)
                )
                slot_budget[d.slot] = d.new
                continue
            if packed_multi and d.migrate:
                dst = next(
                    (
                        ln
                        for ln in packer.lanes
                        if ln.width >= d.new and ln.free
                    ),
                    None,
                )
                if dst is None:
                    # No wide-enough free slot anywhere: the controller
                    # reclassifies the grow as denied and keeps the
                    # cooldown charged (backoff before retrying).
                    ctrl.migration_blocked(d.slot)
                    packed_stats["migrations_blocked"] += 1
                    ev["granted"] = False
                    ev["blocked"] = True
                    continue
                migrate(lane, slot, dst, d, k, ev)
                continue
            if packed_multi and d.kind == "shrink":
                dst = next(
                    (
                        ln
                        for ln in packer.lanes
                        if ln.width >= d.new and ln.free
                    ),
                    None,
                )
                if dst is not None and dst.width < lane.width:
                    # Repack downward: the shrunk request no longer needs
                    # this class's width and a narrower bank has room.
                    migrate(lane, slot, dst, d, k, ev)
                    continue
            lane.state = lane.bank.jit_resize_slot_donated(
                lane.state, jnp.int32(slot), k, jnp.int32(d.new)
            )
            slot_budget[d.slot] = d.new

    def consume_prev(lane):
        """Block on the lane's previous in-flight step (async modes):
        returns its (ESS row, FilterOutput) and records
        dispatch→consumption latency (watchdog-checked)."""
        if lane.prev is None:
            return None
        t0, out = lane.prev
        lane.prev = None
        ess = np.asarray(out.ess, np.float64)
        ms = (time.perf_counter() - t0) * 1e3
        lane.tick_ms.append(ms)
        if monitor is not None:
            monitor.step_watchdog(ms)
        return ess, out

    def global_busy():
        busy = np.zeros(total_slots, bool)
        for lane in lanes:
            for s in lane.active:
                busy[lane.offset + s] = True
        return busy

    def inject_state_faults(tick):
        """Apply due state-surgery faults to busy slots, pre-dispatch.

        Poison is eager ``.at[slot]`` surgery between jitted calls —
        never inside a kernel — so chaos cannot perturb compiled
        programs or trace caches, only the data."""
        from repro.core import faults

        for f in injector.state_faults(tick):
            g = injector.target_slot(f, global_busy())
            if g is None:
                continue  # no busy slot yet: the fault defers a tick
            lane = lane_of[g]
            slot = g - lane.offset
            if f.kind == "nan_lanes":
                lane.state = faults.poison_particle_rows(lane.state, slot)
            else:
                lane.state = faults.poison_weight_row(lane.state, slot)
            injector.applied(f, tick, g)

    def observe_health(tick, examined):
        """One monitor tick from already-materialized per-lane stats.

        ``examined`` pairs each lane with the stats of the state the
        retire scan examines this tick (sync: this tick's output; async:
        the previous tick's — the same one-tick lag elastic accepts).  A
        slot is judged only once its own first step's stats have landed
        (the retire-eligibility condition): a just-admitted slot's row
        still describes the lane's previous occupant.
        """
        ess_g = np.zeros(total_slots, np.float64)
        logz_g = np.zeros(total_slots, np.float64)
        mll_g = np.zeros(total_slots, np.float64)
        busy_g = np.zeros(total_slots, bool)
        exp_g = np.zeros(total_slots, np.int64)
        obs_g = np.zeros(total_slots, np.int64)
        for lane, ess_row, out, steps_np in examined:
            o = lane.offset
            ess_g[o:o + lane.nb] = ess_row
            logz_g[o:o + lane.nb] = np.asarray(out.log_z_inc, np.float64)
            mll_g[o:o + lane.nb] = np.asarray(out.max_loglik, np.float64)
            obs_g[o:o + lane.nb] = steps_np
            for s, req in lane.active.items():
                g = o + s
                if req["admitted_tick"] < tick:
                    busy_g[g] = True
                    exp_g[g] = step_base[g] + (tick - step_base_tick[g])
        return monitor.observe(
            tick, ess_g, logz_g, mll_g, busy_g,
            expected_step=exp_g, observed_step=obs_g,
        )

    def next_key():
        hstats["ladder_keys"] += 1
        return jax.random.fold_in(k_health, hstats["ladder_keys"])

    def ladder_rollback(lane, slot, g, tick):
        """Rung 1: restore the newest clean snapshot via the masked
        cross-width import (ragged lanes only — the draw needs a runtime
        count).  ``pop`` consumes the snapshot: one that fails to clear
        the incident is never restored twice."""
        if not lane.ragged:
            return False
        snap = ring.pop(g)
        if snap is None:
            return False
        n = int(snap["n_active"] or slot_budget[g])
        lane.state = lane.bank.jit_import_slot_donated(
            lane.state,
            jnp.int32(slot),
            jax.tree.map(jnp.asarray, snap["particles"]),
            jnp.asarray(snap["log_w"]),
            next_key(),
            jnp.int32(n),
            jnp.int32(snap["step"]),
        )
        slot_budget[g] = n
        step_base[g] = snap["step"]
        step_base_tick[g] = tick + (1 if async_admit else 0)
        return True

    def ladder_reseed(lane, slot, g, tick):
        """Rung 2: fresh diffuse cloud at the slot's current budget —
        progress (the step counter) kept, so retire math is unchanged."""
        if lane.ragged:
            lane.state = lane.bank.jit_reseed_slot_donated(
                lane.state, jnp.int32(slot), next_key(),
                jnp.int32(slot_budget[g]),
            )
        else:
            lane.state = lane.bank.jit_reseed_slot_donated(
                lane.state, jnp.int32(slot), next_key()
            )
        return True

    def ladder_fallback(lane, slot, g, req, tick):
        """Rung 3: precision fallback — migrate the slot into the
        fp32-policy lane (export → masked import; ``import_slot`` casts
        the rows to the destination policy's compute dtype).  A slot
        whose numerics keep tripping at half precision gets the paper's
        baseline precision instead of dying."""
        if fb_lane is None or lane is fb_lane or not fb_lane.free:
            return False
        rows, lw_row, step_row = lane.bank.jit_export_slot(
            lane.state, jnp.int32(slot)
        )
        dslot = fb_lane.free.pop()
        n = int(min(slot_budget[g], fb_lane.width))
        fb_lane.state = fb_lane.bank.jit_import_slot_donated(
            fb_lane.state,
            jnp.int32(dslot),
            rows,
            lw_row,
            next_key(),
            jnp.int32(n),
            step_row,
        )
        lane.active.pop(slot)
        fb_lane.active[dslot] = req
        lane.free.append(slot)
        g_dst = fb_lane.offset + dslot
        slot_budget[g_dst] = n
        slot_budget[g] = 0
        step_base[g_dst] = step_base[g]
        step_base_tick[g_dst] = step_base_tick[g]
        ring.move(g, g_dst)
        monitor.slot_moved(g, g_dst)
        if ctrl is not None:
            ctrl.slot_moved(g, g_dst)
        monitor.slot_action(g_dst, "fallback", tick)
        hstats["fallback_migrations"] += 1
        return True

    def ladder_reupload(lane, slot, g, req, tick):
        """Stuck rung: the device never got (or lost) this request's
        state — redo the admission upload from the request's own key.
        The request restarts at step 0 (there was no trustworthy
        progress to keep)."""
        k = jax.random.fold_in(k_admit, req["id"])
        row = None
        if prefill is not None:
            row = prefill.rows_for([req["id"]], [lane.width])[0]
        if lane.ragged and row is not None:
            lane.state = lane.bank.jit_init_slot_donated(
                lane.state, jnp.int32(slot), k,
                jnp.int32(req["particles"]), row,
            )
        elif lane.ragged:
            lane.state = lane.bank.jit_init_slot_donated(
                lane.state, jnp.int32(slot), k, jnp.int32(req["particles"])
            )
        elif row is not None:
            lane.state = lane.bank.jit_init_slot_donated(
                lane.state, jnp.int32(slot), k, None, row
            )
        else:
            lane.state = lane.bank.jit_init_slot_donated(
                lane.state, jnp.int32(slot), k
            )
        slot_budget[g] = req["particles"]
        step_base[g] = 0
        step_base_tick[g] = tick + (1 if async_admit else 0)
        ring.clear(g)
        return True

    def apply_ladder(alerts, tick):
        """Escalate each alerting slot one rung: rollback → reseed →
        precision fallback → retire-with-error (stuck slots re-upload →
        retire-with-error).  Each rung is tried once per incident, paced
        by the read-back lag so it gets one validated read before the
        next rung fires."""
        for ev in alerts:
            g = ev.slot
            lane = lane_of[g]
            slot = g - lane.offset
            req = lane.active.get(slot)
            inc = monitor.pending(g)
            if req is None or inc is None:
                continue
            acts = inc["actions"]
            if acts and tick - inc.get("last_action_tick", tick) < obs_lag:
                continue  # last rung not validated yet (read-back lag)
            if inc["kind"] == "stuck":
                if "reupload" not in acts and ladder_reupload(
                    lane, slot, g, req, tick
                ):
                    monitor.slot_action(g, "reupload", tick)
                    hstats["reuploads"] += 1
                    continue
                retire_error(lane, slot, f"unrecoverable:{inc['kind']}", tick)
                continue
            if (
                "rollback" not in acts
                and "reseed" not in acts
                and ladder_rollback(lane, slot, g, tick)
            ):
                monitor.slot_action(g, "rollback", tick)
                hstats["rollbacks"] += 1
                continue
            if "reseed" not in acts and ladder_reseed(lane, slot, g, tick):
                monitor.slot_action(g, "reseed", tick)
                hstats["reseeds"] += 1
                continue
            if "fallback" not in acts and ladder_fallback(
                lane, slot, g, req, tick
            ):
                # action recorded against the destination slot inside
                # (the incident moved with the request)
                continue
            retire_error(lane, slot, f"unrecoverable:{inc['kind']}", tick)

    def push_snapshots(lane, state, tick):
        """Host-side rollback points for healthy busy slots.  The rows
        are validated finite on the host *before* entering the ring — a
        poisoned snapshot would turn rollback into re-poisoning."""
        for s, req in list(lane.active.items()):
            g = lane.offset + s
            if monitor.pending(g) is not None or req["admitted_tick"] >= tick:
                continue
            rows, lw_row, step_row = lane.bank.jit_export_slot(
                state, jnp.int32(s)
            )
            lw = np.asarray(lw_row)
            n = int(slot_budget[g]) if lane.ragged else lane.width
            if not np.all(np.isfinite(lw[:n])):
                continue
            host_rows = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), rows
            )
            if not all(
                np.all(np.isfinite(np.asarray(x, np.float64)))
                for x in jax.tree.leaves(host_rows)
                if x.dtype.kind in "fV"
            ):
                continue
            ring.push(
                g, host_rows, lw, int(np.asarray(step_row)),
                n_active=n if lane.ragged else None, tick=tick,
            )

    if pipelined_uploads:
        # Pipelined mode admits at the *tail* of each tick, so tick 0's
        # arrivals need a pass before the first dispatch.
        admit_all(tick)
    while pending or any(lane.active for lane in lanes):
        if not pipelined_uploads:
            admit_all(tick)
        if injector is not None:
            inject_state_faults(tick)
        dispatches = []
        for lane in lanes:
            keys = lane.step_keys(k_run, tick)
            # Per-tick particle accounting from the *current* budgets
            # (the host mirror), not admission-time ones: under elastic
            # resizes the admission budget is only where a request
            # started.
            busy = [
                int(slot_budget[lane.offset + s]) for s in lane.active
            ]
            t0 = time.perf_counter()
            post, out = lane.step_fn(lane.state, lane.obs, keys)
            if injector is not None:
                attempt = 0
                while injector.step_fails(tick, lane.index, attempt):
                    # Failed dispatch: drop its results and retry from
                    # the (non-donated) pre-step state, bounded backoff.
                    attempt += 1
                    monitor.step_retried()
                    if attempt > health.max_step_retries:
                        post = out = None
                        break
                    post, out = lane.step_fn(lane.state, lane.obs, keys)
                delay = injector.step_delay_ms(tick, lane.index)
                if delay:
                    # Hung-step scenario: the watchdog sees it in this
                    # step's dispatch→consumption wall latency.
                    time.sleep(delay / 1e3)
                if post is None:
                    # Retries exhausted: contain by erroring out the
                    # lane's requests, then re-dispatch (the pre-step
                    # state itself is intact — only dispatch failed).
                    hstats["lane_failures"] += 1
                    for s in sorted(lane.active):
                        retire_error(lane, s, "step_failed", tick)
                    post, out = lane.step_fn(lane.state, lane.obs, keys)
            dispatches.append((lane, busy, t0, post, out))
        if async_admit:
            # Dispatch-first, decide later: the retire pass blocks only
            # on the *pre-step* state (already materialized), and the
            # latency/ESS consumption blocks only on the *previous*
            # tick's step, while this tick's steps run on device.
            prev_rows, examined = [], []
            for lane, busy, t0, post, out in dispatches:
                busy_slot_ticks += len(busy)
                active_particle_ticks += sum(busy)
                padded_particle_ticks += len(busy) * p_max
                packed_stats["lane_particle_ticks"] += len(busy) * lane.width
                pr = consume_prev(lane)
                prev_rows.append(None if pr is None else pr[0])
                if monitor is not None and pr is not None:
                    examined.append(
                        (lane, pr[0], pr[1], np.array(lane.state.step))
                    )
            alerts = []
            if monitor is not None and len(examined) == len(lanes):
                alerts = observe_health(tick, examined)
            for lane, busy, t0, post, out in dispatches:
                retire(lane, lane.state, tick)
            if (
                ring is not None
                and tick
                and tick % health.snapshot_every == 0
            ):
                # Snapshots come off the *pre-step* state — the exact
                # state health just validated, already materialized, so
                # the in-flight step is never waited on.
                for lane, busy, t0, post, out in dispatches:
                    push_snapshots(lane, lane.state, tick)
            for lane, busy, t0, post, out in dispatches:
                lane.state = post
                lane.prev = (t0, out)
            if alerts:
                # Recovery surgery lands on the in-flight step's output
                # (enqueued behind it), like elastic resizes below.
                apply_ladder(alerts, tick)
            if ctrl is not None and prev_rows and prev_rows[0] is not None:
                # One tick of lag: resize from the previous step's ESS
                # (already materialized) so the in-flight step is never
                # waited on; the resizes apply to its output.
                apply_elastic(np.concatenate(prev_rows), tick)
            tick += 1
            if pipelined_uploads:
                # Tail admissions: uploads enqueue against the in-flight
                # step's output and overlap it — the next dispatch finds
                # its slots already written, never a host admission stall.
                admit_all(tick)
        else:
            tick += 1
            examined = []
            for lane, busy, t0, post, out in dispatches:
                lane.state = post
                ess = np.asarray(out.ess, np.float64)
                ms = (time.perf_counter() - t0) * 1e3
                lane.tick_ms.append(ms)
                if monitor is not None:
                    monitor.step_watchdog(ms)
                busy_slot_ticks += len(busy)
                active_particle_ticks += sum(busy)
                padded_particle_ticks += len(busy) * p_max
                packed_stats["lane_particle_ticks"] += len(busy) * lane.width
                examined.append(
                    (lane, ess, out, np.array(lane.state.step))
                )
            alerts = []
            if monitor is not None:
                # Judge the tick's stats *before* the retire scan: a
                # tripped slot must never slip out as a success.
                alerts = observe_health(tick, examined)
            for lane, ess_row, out, steps_np in examined:
                retire(lane, lane.state, tick)
            if alerts:
                apply_ladder(alerts, tick)
            if ctrl is not None:
                apply_elastic(
                    np.concatenate([e[1] for e in examined]), tick
                )
            if (
                ring is not None
                and tick % health.snapshot_every == 0
            ):
                for lane in lanes:
                    push_snapshots(lane, lane.state, tick)
    for lane in lanes:
        consume_prev(lane)  # final in-flight step's latency sample
    results.sort(key=lambda r: r["id"])
    stats = {
        "results": results,
        "ticks": tick,
        "busy_slot_ticks": busy_slot_ticks,
        "occupancy": busy_slot_ticks / max(1, tick * total_slots),
        "active_particle_ticks": active_particle_ticks,
        "padded_particle_ticks": padded_particle_ticks,
        "padding_waste": (
            1.0 - active_particle_ticks / padded_particle_ticks
            if padded_particle_ticks
            else 0.0
        ),
        "elastic": (
            {"events": events, **ctrl.stats} if ctrl is not None else None
        ),
        "latency": _latency_summary(lanes, tick_deadline_ms),
        "packed": (
            {
                "classes": {lane.width: lane.nb for lane in lanes},
                **packed_stats,
            }
            if packed
            else None
        ),
        "prefill": (
            {"prompt_len": prefill.prompt_len, "batches": prefill.batches}
            if prefill is not None
            else None
        ),
        "health": (
            {
                **monitor.stats,
                "snapshots": {
                    "pushes": ring.pushes,
                    "rollbacks": ring.rollbacks,
                },
                "fallback_slots": fb_lane.nb if fb_lane is not None else 0,
                **{
                    k: v for k, v in hstats.items() if k != "ladder_keys"
                },
            }
            if monitor is not None
            else None
        ),
        "chaos": injector.stats if injector is not None else None,
    }
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling, continuous-batched "
                         "(one FilterBank slot per request)")
    ap.add_argument("--slots", type=int, default=4,
                    help="--smc: concurrent request slots in the bank")
    ap.add_argument("--requests", type=int, default=8,
                    help="--smc: total requests to serve")
    ap.add_argument("--particles-per-slot", type=int, default=4)
    ap.add_argument("--particles", default="",
                    help="--smc: per-request particle budgets, either a "
                         "single count or a MIN:MAX range (ragged bank: "
                         "the bank runs at lane width MAX and each request "
                         "draws a key-derived power-of-two size class in "
                         "[MIN, MAX]); overrides --particles-per-slot")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="--smc: ticks between request arrivals")
    ap.add_argument("--elastic", action="store_true",
                    help="--smc ragged banks: ESS-driven per-slot particle "
                         "budget autoscaling — grow a slot on ESS collapse, "
                         "shrink when ESS is healthy (see --elastic-*)")
    ap.add_argument("--elastic-grow", type=float, default=None,
                    help="absolute ESS floor that doubles a busy slot's "
                         "budget (default: MIN/2 from --particles)")
    ap.add_argument("--elastic-shrink", type=float, default=None,
                    help="absolute ESS ceiling that halves a busy slot's "
                         "budget (default: 4x the grow floor)")
    ap.add_argument("--elastic-cooldown", type=int, default=2,
                    help="ticks a slot is frozen after a granted resize")
    ap.add_argument("--elastic-budget", type=int, default=None,
                    help="global cap on total active particles across "
                         "busy slots; grows beyond it are denied in "
                         "ESS-deficit order (default: uncapped)")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--mesh", default="",
                    help="--smc: DxM device mesh, e.g. 2x2 — slots shard "
                         "over 'data' (D), particles over 'model' (M); "
                         "needs D*M visible devices")
    ap.add_argument("--scheme", default="local",
                    choices=["exact", "local"],
                    help="--smc --mesh: distributed resampling scheme")
    ap.add_argument("--async-admit", action="store_true",
                    help="--smc: double-buffered admit/retire overlapping "
                         "the bank step")
    ap.add_argument("--packed", action="store_true",
                    help="--smc: one width-matched bank per particle size "
                         "class (size-class packing) instead of one "
                         "pad-to-MAX bank; requests route to the narrowest "
                         "fitting bank with work-conserving spillover, and "
                         "elastic resizes crossing a class boundary migrate "
                         "the slot across banks")
    ap.add_argument("--prompt-len", type=int, default=0,
                    help="--smc: prompt tokens per request, processed as "
                         "one batched prefill pass per admission tick "
                         "before the slot enters the decode loop "
                         "(0 disables the prefill/decode split)")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="--smc --prompt-len: requests per batched prefill "
                         "pass (default: --slots)")
    ap.add_argument("--pipelined-uploads", action="store_true",
                    help="--smc --async-admit: enqueue admission/migration "
                         "slot uploads behind the in-flight bank step "
                         "instead of ahead of the next dispatch (bitwise "
                         "identical schedule, host never stalls on uploads)")
    ap.add_argument("--tick-deadline-ms", type=float, default=None,
                    help="--smc: per-tick step latency deadline; the "
                         "summary reports p50/p95 and ticks over it")
    ap.add_argument("--health", action="store_true",
                    help="--smc: per-slot health sentinels + the "
                         "escalation ladder (rollback -> reseed -> "
                         "precision fallback -> retire-with-error); "
                         "derived entirely from stats the scheduler "
                         "already reads back — zero extra device passes")
    ap.add_argument("--step-timeout-ms", type=float, default=None,
                    help="--smc --health: wall-clock watchdog on each "
                         "bank step's dispatch->consumption latency")
    ap.add_argument("--health-snapshot-every", type=int, default=4,
                    help="--smc --health: ticks between host-side "
                         "rollback snapshots of every healthy busy slot")
    ap.add_argument("--fp32-fallback", type=int, default=0, metavar="SLOTS",
                    help="--smc --health: reserve SLOTS fp32-policy "
                         "fallback slots; a slot whose numerics keep "
                         "tripping migrates there (precision fallback) "
                         "instead of retiring with an error")
    ap.add_argument("--chaos", action="store_true",
                    help="--smc: deterministic fault injection (implies "
                         "--health): NaN/Inf state poison, dropped "
                         "uploads, failed and delayed steps, the whole "
                         "schedule derived from --seed")
    ap.add_argument("--chaos-classes", default="",
                    help="--chaos: comma-separated fault classes "
                         "(default: all of nan_lanes,inf_weights,"
                         "drop_upload,fail_step,delay_step)")
    ap.add_argument("--chaos-rounds", type=int, default=1,
                    help="--chaos: passes over the fault-class cycle")
    ap.add_argument("--chaos-start", type=int, default=2,
                    help="--chaos: first injection tick")
    ap.add_argument("--chaos-every", type=int, default=3,
                    help="--chaos: ticks between injections")
    ap.add_argument("--chaos-fail-attempts", type=int, default=1,
                    help="--chaos: failing dispatch attempts per "
                         "fail_step fault")
    ap.add_argument("--chaos-delay-ms", type=float, default=25.0,
                    help="--chaos: host delay per delay_step fault")
    ap.add_argument("--elastic-reseed-after", type=int, default=None,
                    help="--smc --elastic: consecutive collapsed ticks "
                         "(ESS under the grow floor at max_particles) "
                         "before the slot is re-seeded from the prior "
                         "(default: --elastic-cooldown; 0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterBank, FilterConfig
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    t0 = time.perf_counter()
    if args.smc:
        spec = make_smc_decode_spec(
            params, cfg, policy, decode,
            temperature=args.temperature, steps=args.steps,
            prompt_len=args.prompt_len,
        )
        # Engine resampling criterion: ESS < frac * particles, exact
        # comparison (frac >= 1 -> resample every step).  With --mesh the
        # bank shards slots x particles over data x model and the
        # distributed scheme resamples every step.
        mesh = None
        if args.mesh:
            from repro import compat

            d, m = (int(x) for x in args.mesh.lower().split("x"))
            mesh = compat.make_mesh(
                (d, m),
                ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2,
            )
        particles = _parse_particles(args)
        p_min, p_max = (
            particles
            if isinstance(particles, tuple)
            else (particles, particles)
        )
        fconfig = FilterConfig(
            policy=policy,
            ess_threshold=args.ess_frac,
            mesh=mesh,
            scheme=args.scheme,
        )
        if args.packed:
            bank = make_packed_banks(
                spec, fconfig,
                num_slots=args.slots, p_min=p_min, p_max=p_max,
            )
        else:
            bank = FilterBank(spec, fconfig, num_slots=args.slots)
        elastic = None
        if args.elastic:
            from repro.core.elastic import ElasticConfig

            if not isinstance(particles, tuple):
                raise SystemExit(
                    "--elastic needs a ragged bank: pass --particles "
                    "MIN:MAX with MIN < MAX"
                )
            grow = (
                args.elastic_grow
                if args.elastic_grow is not None
                else particles[0] / 2
            )
            reseed = args.elastic_reseed_after
            if reseed is None:
                reseed = args.elastic_cooldown
            elastic = ElasticConfig(
                grow_below=grow,
                shrink_above=args.elastic_shrink,
                cooldown=args.elastic_cooldown,
                min_particles=particles[0],
                max_particles=particles[1],
                global_budget=args.elastic_budget,
                reseed_after=reseed or None,
            )
        prefill = None
        if args.prompt_len:
            prefill = PrefillRunner(
                params, cfg, policy, decode,
                prompt_len=args.prompt_len,
                steps=args.steps,
                batch=args.prefill_batch or args.slots,
            )
        health = None
        if args.health or args.chaos:
            from repro.core import HealthConfig

            health = HealthConfig(
                step_timeout_ms=args.step_timeout_ms,
                snapshot_every=args.health_snapshot_every,
            )
        chaos = None
        if args.chaos:
            from repro.core import ChaosConfig

            kw = {}
            if args.chaos_classes:
                kw["classes"] = tuple(args.chaos_classes.split(","))
            chaos = ChaosConfig(
                rounds=args.chaos_rounds,
                start_tick=args.chaos_start,
                every=args.chaos_every,
                fail_attempts=args.chaos_fail_attempts,
                delay_ms=args.chaos_delay_ms,
                **kw,
            )
        fallback_bank = None
        if args.fp32_fallback:
            if health is None:
                raise SystemExit("--fp32-fallback needs --health")
            # The fp32 sibling family: same model, same mesh, the
            # paper's baseline precision — where tripping slots migrate.
            fb_policy = get_policy("fp32")
            decode32 = jax.jit(
                lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, fb_policy)
            )
            spec32 = make_smc_decode_spec(
                params, cfg, fb_policy, decode32,
                temperature=args.temperature, steps=args.steps,
                prompt_len=args.prompt_len,
            )
            fallback_bank = FilterBank(
                spec32,
                FilterConfig(
                    policy=fb_policy,
                    ess_threshold=args.ess_frac,
                    mesh=mesh,
                    scheme=args.scheme,
                ),
                num_slots=args.fp32_fallback,
            )
        stats = run_continuous_batching(
            bank,
            num_requests=args.requests,
            max_steps=args.steps,
            particles=particles,
            key=jax.random.key(args.seed),
            arrival_every=args.arrival_every,
            async_admit=args.async_admit,
            elastic=elastic,
            prefill=prefill,
            pipelined_uploads=args.pipelined_uploads,
            tick_deadline_ms=args.tick_deadline_ms,
            health=health,
            chaos=chaos,
            fallback_bank=fallback_bank,
        )
        dt = time.perf_counter() - t0
        n_steps = sum(r["steps"] for r in stats["results"])
        ticks = max(1, stats["ticks"])
        pdesc = (
            f"{particles[0]}:{particles[1]}"
            if isinstance(particles, tuple)
            else str(particles)
        )
        print(
            f"arch={cfg.name} smc slots={args.slots} "
            f"requests={args.requests} particles/slot={pdesc}"
            + (f" mesh={args.mesh} scheme={args.scheme}" if mesh else "")
            + (" async" if args.async_admit else "")
            + (" pipelined" if args.pipelined_uploads else "")
            + (" packed" if args.packed else "")
            + (" elastic" if elastic is not None else "")
            + (" health" if health is not None else "")
            + (" chaos" if chaos is not None else "")
            + (
                f" fp32-fallback={args.fp32_fallback}"
                if fallback_bank is not None
                else ""
            )
            + (f" prefill={args.prompt_len}" if prefill is not None else "")
            + f" ticks={stats['ticks']} "
            f"occupancy={stats['occupancy']:.0%} "
            f"padding_waste={stats['padding_waste']:.0%} "
            f"({dt / ticks * 1e3:.1f} ms/tick incl. compile, "
            f"{n_steps / dt:.1f} request-steps/s)"
        )
        lat = stats["latency"]
        print(
            f"  latency: p50={lat['p50_ms']:.2f}ms p95={lat['p95_ms']:.2f}ms "
            f"max={lat['max_ms']:.2f}ms over {lat['ticks']} step samples"
            + (
                f"; over_deadline={lat['ticks_over_deadline']} "
                f"(deadline={lat['deadline_ms']:.1f}ms)"
                if lat["deadline_ms"] is not None
                else ""
            )
        )
        pk = stats["packed"]
        if pk is not None:
            classes = " ".join(
                f"{w}p x{n}" for w, n in sorted(pk["classes"].items())
            )
            print(
                f"  packed: classes=[{classes}] "
                f"spillover={pk['spillover_admissions']} "
                f"migrations={pk['migrations']} "
                f"blocked={pk['migrations_blocked']}"
            )
        pf = stats["prefill"]
        if pf is not None:
            print(
                f"  prefill: prompt_len={pf['prompt_len']} "
                f"batched_passes={pf['batches']}"
            )
        el = stats["elastic"]
        if el is not None:
            print(
                f"  elastic: grows={el['grows']} shrinks={el['shrinks']} "
                f"denied_grows={el['denied_grows']} "
                f"denied_latency={el['denied_grows_latency']} "
                f"reseeds={el['reseeds']} "
                f"global_budget={args.elastic_budget or 'uncapped'}"
            )
            for e in el["events"][:8]:
                print(
                    f"    tick {e['tick']:>3} slot {e['slot']}: "
                    f"{e['kind']} {e['old']}->{e['new']} "
                    f"ess={e['ess']:.1f}"
                    + (
                        f" deficit={e['deficit']:.1f}"
                        if e["kind"] == "grow"
                        else ""
                    )
                    + ("" if e["granted"] else " DENIED")
                )
            if len(el["events"]) > 8:
                print(f"    ... {len(el['events']) - 8} more events")
        hl = stats["health"]
        if hl is not None:
            trips = " ".join(
                f"{k}={v}" for k, v in sorted(hl["trips"].items())
            ) or "none"
            recs = " ".join(
                f"{k}={v}" for k, v in sorted(hl["recoveries"].items())
            ) or "none"
            print(
                f"  health: trips[{trips}] recoveries[{recs}] "
                f"watchdog={hl['watchdog_trips']} "
                f"retries={hl['step_retries']} "
                f"snapshots={hl['snapshots']['pushes']} "
                f"rollbacks={hl['snapshots']['rollbacks']} "
                f"fallback_migrations={hl['fallback_migrations']} "
                f"retired_error={hl['retired_error']}"
            )
            for r in hl["recovered"][:6]:
                print(
                    f"    slot {r['slot']}: {r['kind']} "
                    f"@tick {r['trip_tick']} -> {r['action']} "
                    f"in {r['latency_ticks']} ticks"
                )
        ch = stats["chaos"]
        if ch is not None:
            by = collections.Counter(f["kind"] for f in ch["log"])
            print(
                f"  chaos: applied={ch['applied']}/{ch['scheduled']} "
                + " ".join(f"{k}={v}" for k, v in sorted(by.items()))
            )
        errors = [r for r in stats["results"] if "error" in r]
        if errors:
            print(
                "  errored: "
                + " ".join(f"req[{r['id']}]={r['error']}" for r in errors)
            )
        for r in stats["results"][:4]:
            pdesc2 = str(r["particles"])
            if r["final_particles"] != r["particles"]:
                pdesc2 += f"->{r['final_particles']}"
            print(
                f"  req[{r['id']}] steps={r['steps']} "
                f"particles={pdesc2} "
                f"latency={r['finished_tick'] - r['admitted_tick']} ticks: "
                f"{r['tokens'][:12].tolist()}..."
            )
        return
    cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
    tok = jnp.zeros((b,), jnp.int32)
    seqs = np.zeros((b, args.steps), np.int32)
    key = jax.random.key(args.seed)
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        key, k1 = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                k1, logits / args.temperature, -1
            )
        else:
            tok = jnp.argmax(logits, -1)
        seqs[:, i] = np.asarray(tok)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} independent batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)")
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _parse_particles(args) -> int | tuple[int, int]:
    """``--particles`` ("N" or "MIN:MAX") with --particles-per-slot fallback."""
    if not args.particles:
        return args.particles_per_slot
    spec = args.particles
    if ":" in spec:
        lo, hi = (int(x) for x in spec.split(":", 1))
        if not 1 <= lo <= hi:
            raise SystemExit(
                f"--particles range must satisfy 1 <= MIN <= MAX, got {spec}"
            )
        return lo if lo == hi else (lo, hi)
    return int(spec)


def _batch_axis(x, n):
    """The unique axis of extent ``n`` — raises when absent *or ambiguous*.

    Guessing "first dimension equal to n" silently picks the wrong axis for
    square shapes (batch == seq-len, batch == num-layers, ...); callers
    that know the layout should thread the axis through instead (see
    ``make_smc_decode_spec``'s cache_axes).
    """
    hits = [i for i, d in enumerate(x.shape) if d == n]
    if not hits:
        raise ValueError(f"no batch axis of extent {n} in {x.shape}")
    if len(hits) > 1:
        raise ValueError(
            f"ambiguous batch axis: {len(hits)} dimensions of extent {n} "
            f"in {x.shape} (axes {hits}); thread the known axis through "
            "instead of guessing"
        )
    return hits[0]


def _changed_axis(shape_a: tuple, shape_b: tuple) -> int:
    """The single axis on which two layouts of different batch size differ."""
    diff = [i for i, (a, b) in enumerate(zip(shape_a, shape_b)) if a != b]
    if len(diff) != 1:
        raise ValueError(
            f"expected exactly one batch-dependent axis, got {diff} "
            f"between {shape_a} and {shape_b}"
        )
    return diff[0]


def _is_param_spec(x) -> bool:
    from repro.models.params import ParamSpec

    return isinstance(x, ParamSpec)


if __name__ == "__main__":
    main()
