"""Batched serving driver: prefill queue -> continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --slots 4 --requests 8 \
        --particles 4:32 --mesh 2x2 --async-admit]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``).  The SMC path is the engine
API end to end: decoding is expressed as an ``SMCSpec`` (one particle =
one partial sequence, its cache the state; propagation = sample a token;
weight = model log-prob at T=1) and served by a ``FilterBank`` — one slot
per in-flight request, ``--particles-per-slot`` particles each — under a
continuous-batching scheduler: requests are admitted into free slots
mid-flight, retired on completion, and the bank steps every tick regardless
of occupancy (the scheduler never waits to fill the batch and never
recompiles; slot lifecycle is ``reset_slot`` by traced index).

Particle budgets are per request (``--particles MIN:MAX``): the bank is a
*ragged* FilterBank at lane width MAX, each request draws a key-derived
power-of-two size class in [MIN, MAX] and is admitted at that traced
active count (``reset_slot(..., n_active=n)`` — no recompile per size),
so easy requests stop paying for the hardest request's particle cloud.
The scheduler reports the padding this removes from the quality ledger
(``padding_waste``: active vs padded particle-ticks).  A single
``--particles N`` (or the legacy ``--particles-per-slot``) keeps the dense
bank and its mask-free fast path.

``--elastic`` makes those budgets *starting points*: an ESS-driven
``BudgetController`` (``repro.core.elastic``) grows a slot whose ESS
collapses and shrinks one whose ESS is comfortably high, via the bank's
traced ``resize_slot`` budget switch (no recompiles), optionally under a
global particle budget arbitrated by ESS deficit (``--elastic-budget``).
Per-tick decisions are reported, and all padding/particle-tick accounting
follows the *current* budgets rather than admission-time ones.

The bank composes with a device mesh (``--mesh DxM``): slots shard over
the "data" axis and each slot's particles over "model" (the engine's
mesh × bank composition — ``repro.core.distributed.make_dist_bank_step``),
so the same scheduler serves a multi-device bank unchanged: admissions
place their traced-index reset onto the owning shard, and every retire
reads back only that request's slot.  ``--async-admit`` switches the
scheduler to the double-buffered path: the next bank step is dispatched
*before* the host blocks on the previous tick's counters, so admit/retire
bookkeeping (and the host↔device slot swaps it triggers) overlap device
compute instead of serializing with it — identical schedules, one step of
read-back lag.
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_smc_decode_spec(
    params, cfg, policy, decode, *, temperature: float, steps: int
):
    """SMC decoding as a particle-filter model.

    Particle state: token, KV/recurrent cache, last reward, token history.
    The transition runs one batched decode step and samples at the
    exploration temperature; the likelihood is the reward recorded by the
    transition (the model's own T=1 log-prob of the sampled token).
    ``gather`` selects ancestors along each cache leaf's *known* particle
    axis — derived once from the cache layout, never guessed from shapes
    (a dimension that merely equals the particle count, e.g. a layer or
    head count, must not be mistaken for the batch axis); ``summary`` keeps
    the per-step estimate to one scalar (mean reward) instead of averaging
    whole caches.  ``steps`` sizes the cache/history buffers — the *maximum*
    request length a serving slot can hold.
    """
    from repro.core.filter import SMCSpec
    from repro.models import model as M

    # Per-leaf particle axis: the one dimension whose extent follows the
    # batch argument of the cache layout (shape-only — nothing allocated).
    cache_axes = jax.tree.map(
        lambda a, b: _changed_axis(a.shape, b.shape),
        M.cache_specs(cfg, 2, steps + 1),
        M.cache_specs(cfg, 3, steps + 1),
        is_leaf=_is_param_spec,
    )

    def init(key, n):
        del key
        return {
            "tok": jnp.zeros((n,), jnp.int32),
            "cache": M.init_cache(cfg, n, steps + 1, policy.compute_dtype),
            "reward": jnp.zeros((n,), jnp.float32),
            # Lineage log-prob: cumulative reward along the surviving
            # ancestry (travels through resampling gathers), since the
            # engine renormalizes the filter weights every step.
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        logits, cache = decode(
            params, p["tok"], step.astype(jnp.int32), p["cache"]
        )
        logits = logits.astype(jnp.float32)
        if temperature > 0:
            tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        logp = jax.nn.log_softmax(logits, -1)
        reward = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        # Freed slots keep ticking past their request's budget ("the bank
        # steps every tick"); clamp the history index so their writes land
        # on the last column instead of out of bounds.
        pos = jnp.minimum(step, steps - 1)
        return {
            "tok": tok,
            "cache": cache,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    def gather(p, anc):
        return {
            "tok": jnp.take(p["tok"], anc, axis=0),
            "cache": jax.tree.map(
                lambda x, ax: jnp.take(x, anc, axis=ax),
                p["cache"],
                cache_axes,
            ),
            "reward": jnp.take(p["reward"], anc, axis=0),
            "cum_reward": jnp.take(p["cum_reward"], anc, axis=0),
            "seq": jnp.take(p["seq"], anc, axis=0),
        }

    def summary(p, w):
        return {"reward": jnp.sum(w * p["reward"].astype(w.dtype))}

    return SMCSpec(
        init,
        transition,
        loglik,
        gather=gather,
        summary=summary,
        particle_axes={
            "tok": 0,
            "cache": cache_axes,
            "reward": 0,
            "cum_reward": 0,
            "seq": 0,
        },
    )


def _request_budgets(
    key: jax.Array, num_requests: int, min_steps: int, max_steps: int
) -> np.ndarray:
    """Per-request decode budgets in [min_steps, max_steps], keyed.

    The whole workload derives from the scheduler key — two seeds draw two
    schedules, one seed reproduces (the old hardcoded
    ``np.random.default_rng(0)`` made every ``--seed`` serve the same
    traffic).
    """
    return np.array(
        jax.random.randint(key, (num_requests,), min_steps, max_steps + 1)
    )


def particle_size_classes(p_min: int, p_max: int) -> list[int]:
    """Power-of-two ladder of particle budgets from p_min up to p_max.

    Requests are binned into these classes rather than arbitrary counts so
    a packer can group same-class requests (and so budgets stay friendly to
    lane-width-128 kernels): [p_min, 2*p_min, 4*p_min, ..., p_max].
    """
    if not 1 <= p_min <= p_max:
        raise ValueError(
            f"need 1 <= min <= max particle budgets, got {p_min}:{p_max}"
        )
    classes = []
    c = p_min
    while c < p_max:
        classes.append(c)
        c *= 2
    classes.append(p_max)
    return classes


def _request_particles(
    key: jax.Array, num_requests: int, p_min: int, p_max: int
) -> np.ndarray:
    """Key-derived per-request particle budgets drawn from the size-class
    ladder — the heterogeneous-difficulty workload of a ragged bank."""
    classes = np.asarray(particle_size_classes(p_min, p_max))
    idx = np.asarray(
        jax.random.randint(key, (num_requests,), 0, len(classes))
    )
    return classes[idx]


def run_continuous_batching(
    bank,
    *,
    num_requests: int,
    max_steps: int,
    particles: int | tuple[int, int],
    key: jax.Array,
    arrival_every: int = 1,
    min_steps: int | None = None,
    async_admit: bool = False,
    elastic=None,
) -> dict:
    """Admit → step → retire loop over a FilterBank of decode slots.

    Requests arrive on a fixed schedule (request ``i`` at tick
    ``i * arrival_every``) with key-derived budgets in
    [min_steps, max_steps].  A free slot is claimed by ``reset_slot``
    (traced slot index — no recompile); the whole bank steps every tick
    whether or not every slot holds a request; a slot retires the moment
    its step counter reaches its request's budget, returning the
    highest-cumulative-reward particle's sequence.  Works unchanged over a
    mesh-sharded bank (``FilterConfig(mesh=...)``): resets land on the
    owning shard, retires read back per-slot rows.

    ``particles`` may be a single count (dense bank, every request at the
    same width) or a ``(min, max)`` range — the *ragged* bank: the bank is
    built at lane width ``max``, every request draws a key-derived particle
    budget from the power-of-two size-class ladder in [min, max]
    (:func:`particle_size_classes`), and admission resets the slot at that
    *traced* count (``reset_slot(..., n_active=n)`` — no recompile per
    size).  Easy requests then carry e.g. 256 active lanes while hard ones
    carry 4096 in the same bank, and the returned stats report the padding
    this saves from the quality ledger: ``active_particle_ticks`` (lanes
    that did useful work), ``padded_particle_ticks`` (lanes a pad-to-max
    bank would bill), and ``padding_waste`` (their gap as a fraction).

    ``async_admit`` double-buffers the loop: each tick's bank step is
    dispatched *before* the host blocks on the previous tick's counters,
    so retire/extract bookkeeping overlaps device compute (the retiring
    slot's data is read from the already-materialized pre-step state — the
    in-flight step never gates a host decision).  While free slots exist
    the schedule matches the synchronous path tick for tick; when a
    request queues for a freed slot its admission lags by one tick — the
    price of never stalling the device on a host decision.  Returns
    per-request results plus occupancy/latency stats.

    ``elastic`` (an :class:`repro.core.elastic.ElasticConfig`, ragged banks
    only) turns admission budgets into *starting* budgets: a
    ``BudgetController`` watches each busy slot's per-step ESS (free from
    the fused epilogue's stats) and rewrites its active count in flight via
    ``bank.resize_slot`` — grow on ESS collapse, shrink when healthy, with
    deadband + cooldown and an optional global particle budget arbitrated
    by ESS deficit.  Synchronous ticks resize from the tick's own ESS;
    async ticks apply the *previous* tick's already-materialized ESS so
    the in-flight step is never waited on (one tick of controller lag, the
    same lag the scheduler already accepts for retires).  All particle-tick
    accounting (``active``/``padded``/``padding_waste``) and the retire
    read of the best particle use the slot's *current* budget from a host
    mirror updated at admit and at every granted resize, so the ledger
    stays truthful as budgets move mid-flight.  Decisions are returned in
    ``stats["elastic"]`` (per-event tick/slot/kind/ess/deficit plus
    grow/shrink/denied counters).
    """
    nb = bank.num_slots
    if min_steps is None:
        min_steps = max(1, max_steps // 2)
    if not 0 <= min_steps <= max_steps:
        raise ValueError(
            f"need 0 <= min_steps <= max_steps, got min_steps={min_steps}, "
            f"max_steps={max_steps}"
        )
    if isinstance(particles, tuple):
        p_min, p_max = particles
    else:
        p_min = p_max = particles
    ragged = p_min < p_max
    ctrl = None
    if elastic is not None:
        from repro.core.elastic import BudgetController

        if not ragged:
            raise ValueError(
                "elastic budgets need a ragged bank: pass "
                "particles=(MIN, MAX) with MIN < MAX so per-slot counts "
                "are runtime values"
            )
        if elastic.max_particles > p_max:
            raise ValueError(
                f"elastic.max_particles={elastic.max_particles} exceeds "
                f"the bank's lane width {p_max}"
            )
        ctrl = BudgetController(elastic, nb)
    k_state, k_admit, k_run, k_sched, k_elastic = jax.random.split(key, 5)
    lengths = _request_budgets(k_sched, num_requests, min_steps, max_steps)
    if ragged:
        budgets = _request_particles(
            jax.random.fold_in(k_sched, 1), num_requests, p_min, p_max
        )
    else:
        budgets = np.full((num_requests,), p_max)
    pending = collections.deque(
        {
            "id": i,
            "steps": int(lengths[i]),
            "particles": int(budgets[i]),
            "arrival": i * arrival_every,
        }
        for i in range(num_requests)
    )
    if ragged:
        # Ragged states must be ragged from init (the pytree cannot grow a
        # count field under jit); empty slots idle at full width.
        state = bank.init(
            k_state, p_max, n_active=jnp.full((nb,), p_max, jnp.int32)
        )
    else:
        state = bank.init(k_state, p_max)
    obs = jnp.zeros((nb,), jnp.int32)  # the decode spec ignores observations
    # Synchronous ticks donate the bank state: step and admission reuse the
    # particle/weight/cache buffers in place instead of copying them every
    # tick (the pre-step state is never read after the call).  The async
    # path must NOT donate its step input — retire reads the *pre-step*
    # state while the step runs on device, so aliasing those buffers would
    # hand retire reclaimed memory.
    if async_admit:
        step = bank.jit_step
        reset = bank.jit_init_slot_donated
    else:
        step = bank.jit_step_donated
        reset = bank.jit_init_slot_donated
    active: dict[int, dict] = {}
    free = list(range(nb))[::-1]
    results, tick, busy_slot_ticks = [], 0, 0
    active_particle_ticks, padded_particle_ticks = 0, 0
    # Host mirror of each slot's *current* particle budget.  Admission
    # seeds it; every granted elastic resize updates it; all particle-tick
    # accounting and retire reads go through it instead of the
    # admission-time ``req["particles"]`` (stale once budgets move).
    slot_budget = np.zeros(nb, np.int64)
    events: list[dict] = []

    def admit(state, tick):
        while free and pending and pending[0]["arrival"] <= tick:
            req = pending.popleft()
            slot = free.pop()
            if ragged:
                state = reset(
                    state,
                    jnp.int32(slot),
                    jax.random.fold_in(k_admit, req["id"]),
                    jnp.int32(req["particles"]),
                )
            else:
                state = reset(
                    state,
                    jnp.int32(slot),
                    jax.random.fold_in(k_admit, req["id"]),
                )
            req["admitted_tick"] = tick
            active[slot] = req
            slot_budget[slot] = req["particles"]
            if ctrl is not None:
                # Grace period: a fresh request's first ESS readings are
                # noise; hold resizes for one full cooldown window.
                ctrl.slot_admitted(slot)
        return state

    def retire(ex_state, ex_tick):
        """Retire against a state holding ``ex_tick`` completed steps."""
        if not active:
            return
        steps_now = np.asarray(ex_state.step)
        done = [
            s
            for s in active
            if active[s]["admitted_tick"] < ex_tick
            and steps_now[s] >= active[s]["steps"]
        ]
        if not done:
            return
        cum = np.asarray(ex_state.particles["cum_reward"], np.float32)
        seqs = np.asarray(ex_state.particles["seq"])
        for slot in done:
            req = active.pop(slot)
            # Best particle over the slot's *currently active* lanes only —
            # lanes beyond the current budget hold junk (a shrunk slot's
            # old lanes included) that must never win the argmax.
            n_now = int(slot_budget[slot])
            best = int(np.argmax(cum[slot, :n_now]))
            results.append(
                {
                    "id": req["id"],
                    "steps": req["steps"],
                    "particles": req["particles"],
                    "final_particles": n_now,
                    # A real copy, not a view: np.asarray above is
                    # zero-copy into the jax buffer, and a live external
                    # view would block the donated step/reset from
                    # aliasing the bank state on every later tick (and
                    # pin the whole (nb, P, steps) seq array per retired
                    # request until the run ends).
                    "tokens": np.array(seqs[slot, best, : req["steps"]]),
                    "admitted_tick": req["admitted_tick"],
                    "finished_tick": ex_tick,
                }
            )
            free.append(slot)

    def apply_elastic(state, ess, tick):
        """Run one controller tick and apply granted resizes to ``state``.

        ``state`` here is always the freshest bank state (post-step) and
        nothing else reads it afterward, so the donated resize is safe.
        """
        busy_mask = np.zeros(nb, bool)
        for s in active:
            busy_mask[s] = True
        for d in ctrl.observe(ess, slot_budget, busy_mask):
            events.append(
                {
                    "tick": tick,
                    "slot": d.slot,
                    "old": d.old,
                    "new": d.new,
                    "ess": d.ess,
                    "kind": d.kind,
                    "granted": d.granted,
                    "deficit": d.deficit,
                }
            )
            if d.granted:
                state = bank.jit_resize_slot_donated(
                    state,
                    jnp.int32(d.slot),
                    jax.random.fold_in(k_elastic, len(events)),
                    jnp.int32(d.new),
                )
                slot_budget[d.slot] = d.new
        return state

    prev_ess = None
    while pending or active:
        state = admit(state, tick)
        keys = jax.random.split(jax.random.fold_in(k_run, tick), nb)
        # Per-tick particle accounting from the *current* budgets (the
        # host mirror), not admission-time ones: under elastic resizes the
        # admission budget is only where a request started.
        busy = [int(slot_budget[s]) for s in active]
        if async_admit:
            # Dispatch first, decide later: the retire pass below blocks
            # only on the *pre-step* state (already materialized), while
            # this tick's step runs on device.
            new_state, out = step(state, obs, keys)
            busy_slot_ticks += len(busy)
            active_particle_ticks += sum(busy)
            padded_particle_ticks += len(busy) * p_max
            retire(state, tick)
            if ctrl is not None and prev_ess is not None:
                # One tick of lag: resize from the previous step's ESS
                # (already materialized) so the in-flight step is never
                # waited on; the resize applies to its output.
                new_state = apply_elastic(
                    new_state, np.asarray(prev_ess, np.float64), tick
                )
            if ctrl is not None:
                prev_ess = out.ess
            state = new_state
            tick += 1
        else:
            state, out = step(state, obs, keys)
            tick += 1
            busy_slot_ticks += len(busy)
            active_particle_ticks += sum(busy)
            padded_particle_ticks += len(busy) * p_max
            retire(state, tick)
            if ctrl is not None:
                state = apply_elastic(
                    state, np.asarray(out.ess, np.float64), tick
                )
    results.sort(key=lambda r: r["id"])
    return {
        "results": results,
        "ticks": tick,
        "busy_slot_ticks": busy_slot_ticks,
        "occupancy": busy_slot_ticks / max(1, tick * nb),
        "active_particle_ticks": active_particle_ticks,
        "padded_particle_ticks": padded_particle_ticks,
        "padding_waste": (
            1.0 - active_particle_ticks / padded_particle_ticks
            if padded_particle_ticks
            else 0.0
        ),
        "elastic": (
            {"events": events, **ctrl.stats} if ctrl is not None else None
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling, continuous-batched "
                         "(one FilterBank slot per request)")
    ap.add_argument("--slots", type=int, default=4,
                    help="--smc: concurrent request slots in the bank")
    ap.add_argument("--requests", type=int, default=8,
                    help="--smc: total requests to serve")
    ap.add_argument("--particles-per-slot", type=int, default=4)
    ap.add_argument("--particles", default="",
                    help="--smc: per-request particle budgets, either a "
                         "single count or a MIN:MAX range (ragged bank: "
                         "the bank runs at lane width MAX and each request "
                         "draws a key-derived power-of-two size class in "
                         "[MIN, MAX]); overrides --particles-per-slot")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="--smc: ticks between request arrivals")
    ap.add_argument("--elastic", action="store_true",
                    help="--smc ragged banks: ESS-driven per-slot particle "
                         "budget autoscaling — grow a slot on ESS collapse, "
                         "shrink when ESS is healthy (see --elastic-*)")
    ap.add_argument("--elastic-grow", type=float, default=None,
                    help="absolute ESS floor that doubles a busy slot's "
                         "budget (default: MIN/2 from --particles)")
    ap.add_argument("--elastic-shrink", type=float, default=None,
                    help="absolute ESS ceiling that halves a busy slot's "
                         "budget (default: 4x the grow floor)")
    ap.add_argument("--elastic-cooldown", type=int, default=2,
                    help="ticks a slot is frozen after a granted resize")
    ap.add_argument("--elastic-budget", type=int, default=None,
                    help="global cap on total active particles across "
                         "busy slots; grows beyond it are denied in "
                         "ESS-deficit order (default: uncapped)")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--mesh", default="",
                    help="--smc: DxM device mesh, e.g. 2x2 — slots shard "
                         "over 'data' (D), particles over 'model' (M); "
                         "needs D*M visible devices")
    ap.add_argument("--scheme", default="local",
                    choices=["exact", "local"],
                    help="--smc --mesh: distributed resampling scheme")
    ap.add_argument("--async-admit", action="store_true",
                    help="--smc: double-buffered admit/retire overlapping "
                         "the bank step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterBank, FilterConfig
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    t0 = time.perf_counter()
    if args.smc:
        spec = make_smc_decode_spec(
            params, cfg, policy, decode,
            temperature=args.temperature, steps=args.steps,
        )
        # Engine resampling criterion: ESS < frac * particles, exact
        # comparison (frac >= 1 -> resample every step).  With --mesh the
        # bank shards slots x particles over data x model and the
        # distributed scheme resamples every step.
        mesh = None
        if args.mesh:
            from repro import compat

            d, m = (int(x) for x in args.mesh.lower().split("x"))
            mesh = compat.make_mesh(
                (d, m),
                ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2,
            )
        bank = FilterBank(
            spec,
            FilterConfig(
                policy=policy,
                ess_threshold=args.ess_frac,
                mesh=mesh,
                scheme=args.scheme,
            ),
            num_slots=args.slots,
        )
        particles = _parse_particles(args)
        elastic = None
        if args.elastic:
            from repro.core.elastic import ElasticConfig

            if not isinstance(particles, tuple):
                raise SystemExit(
                    "--elastic needs a ragged bank: pass --particles "
                    "MIN:MAX with MIN < MAX"
                )
            grow = (
                args.elastic_grow
                if args.elastic_grow is not None
                else particles[0] / 2
            )
            elastic = ElasticConfig(
                grow_below=grow,
                shrink_above=args.elastic_shrink,
                cooldown=args.elastic_cooldown,
                min_particles=particles[0],
                max_particles=particles[1],
                global_budget=args.elastic_budget,
            )
        stats = run_continuous_batching(
            bank,
            num_requests=args.requests,
            max_steps=args.steps,
            particles=particles,
            key=jax.random.key(args.seed),
            arrival_every=args.arrival_every,
            async_admit=args.async_admit,
            elastic=elastic,
        )
        dt = time.perf_counter() - t0
        n_steps = sum(r["steps"] for r in stats["results"])
        ticks = max(1, stats["ticks"])
        pdesc = (
            f"{particles[0]}:{particles[1]}"
            if isinstance(particles, tuple)
            else str(particles)
        )
        print(
            f"arch={cfg.name} smc slots={args.slots} "
            f"requests={args.requests} particles/slot={pdesc}"
            + (f" mesh={args.mesh} scheme={args.scheme}" if mesh else "")
            + (" async" if args.async_admit else "")
            + (" elastic" if elastic is not None else "")
            + f" ticks={stats['ticks']} "
            f"occupancy={stats['occupancy']:.0%} "
            f"padding_waste={stats['padding_waste']:.0%} "
            f"({dt / ticks * 1e3:.1f} ms/tick incl. compile, "
            f"{n_steps / dt:.1f} request-steps/s)"
        )
        el = stats["elastic"]
        if el is not None:
            print(
                f"  elastic: grows={el['grows']} shrinks={el['shrinks']} "
                f"denied_grows={el['denied_grows']} "
                f"global_budget={args.elastic_budget or 'uncapped'}"
            )
            for e in el["events"][:8]:
                print(
                    f"    tick {e['tick']:>3} slot {e['slot']}: "
                    f"{e['kind']} {e['old']}->{e['new']} "
                    f"ess={e['ess']:.1f}"
                    + (
                        f" deficit={e['deficit']:.1f}"
                        if e["kind"] == "grow"
                        else ""
                    )
                    + ("" if e["granted"] else " DENIED")
                )
            if len(el["events"]) > 8:
                print(f"    ... {len(el['events']) - 8} more events")
        for r in stats["results"][:4]:
            pdesc2 = str(r["particles"])
            if r["final_particles"] != r["particles"]:
                pdesc2 += f"->{r['final_particles']}"
            print(
                f"  req[{r['id']}] steps={r['steps']} "
                f"particles={pdesc2} "
                f"latency={r['finished_tick'] - r['admitted_tick']} ticks: "
                f"{r['tokens'][:12].tolist()}..."
            )
        return
    cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
    tok = jnp.zeros((b,), jnp.int32)
    seqs = np.zeros((b, args.steps), np.int32)
    key = jax.random.key(args.seed)
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        key, k1 = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                k1, logits / args.temperature, -1
            )
        else:
            tok = jnp.argmax(logits, -1)
        seqs[:, i] = np.asarray(tok)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} independent batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)")
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _parse_particles(args) -> int | tuple[int, int]:
    """``--particles`` ("N" or "MIN:MAX") with --particles-per-slot fallback."""
    if not args.particles:
        return args.particles_per_slot
    spec = args.particles
    if ":" in spec:
        lo, hi = (int(x) for x in spec.split(":", 1))
        if not 1 <= lo <= hi:
            raise SystemExit(
                f"--particles range must satisfy 1 <= MIN <= MAX, got {spec}"
            )
        return lo if lo == hi else (lo, hi)
    return int(spec)


def _batch_axis(x, n):
    """The unique axis of extent ``n`` — raises when absent *or ambiguous*.

    Guessing "first dimension equal to n" silently picks the wrong axis for
    square shapes (batch == seq-len, batch == num-layers, ...); callers
    that know the layout should thread the axis through instead (see
    ``make_smc_decode_spec``'s cache_axes).
    """
    hits = [i for i, d in enumerate(x.shape) if d == n]
    if not hits:
        raise ValueError(f"no batch axis of extent {n} in {x.shape}")
    if len(hits) > 1:
        raise ValueError(
            f"ambiguous batch axis: {len(hits)} dimensions of extent {n} "
            f"in {x.shape} (axes {hits}); thread the known axis through "
            "instead of guessing"
        )
    return hits[0]


def _changed_axis(shape_a: tuple, shape_b: tuple) -> int:
    """The single axis on which two layouts of different batch size differ."""
    diff = [i for i, (a, b) in enumerate(zip(shape_a, shape_b)) if a != b]
    if len(diff) != 1:
        raise ValueError(
            f"expected exactly one batch-dependent axis, got {diff} "
            f"between {shape_a} and {shape_b}"
        )
    return diff[0]


def _is_param_spec(x) -> bool:
    from repro.models.params import ParamSpec

    return isinstance(x, ParamSpec)


if __name__ == "__main__":
    main()
