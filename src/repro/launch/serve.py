"""Batched serving driver: prefill queue -> continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduce \
        --batch 8 --steps 32 [--smc --slots 4 --requests 8 \
        --particles-per-slot 4]

Demonstrates the serving stack end to end on CPU with a reduced config:
sharded weights, ring-buffer/sliding caches, one fused decode step for the
whole batch, greedy or temperature sampling — and optionally the paper's
particle filter as the sampler (``--smc``).  The SMC path is the engine
API end to end: decoding is expressed as an ``SMCSpec`` (one particle =
one partial sequence, its cache the state; propagation = sample a token;
weight = model log-prob at T=1) and served by a ``FilterBank`` — one slot
per in-flight request, ``--particles-per-slot`` particles each — under a
continuous-batching scheduler: requests are admitted into free slots
mid-flight, retired on completion, and the bank steps every tick regardless
of occupancy (the scheduler never waits to fill the batch and never
recompiles; slot lifecycle is ``reset_slot`` by traced index).
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_smc_decode_spec(
    params, cfg, policy, decode, *, temperature: float, steps: int
):
    """SMC decoding as a particle-filter model.

    Particle state: token, KV/recurrent cache, last reward, token history.
    The transition runs one batched decode step and samples at the
    exploration temperature; the likelihood is the reward recorded by the
    transition (the model's own T=1 log-prob of the sampled token).
    ``gather`` locates the particle axis per cache leaf; ``summary`` keeps
    the per-step estimate to one scalar (mean reward) instead of averaging
    whole caches.  ``steps`` sizes the cache/history buffers — the *maximum*
    request length a serving slot can hold.
    """
    from repro.core.filter import SMCSpec
    from repro.models import model as M

    def init(key, n):
        del key
        return {
            "tok": jnp.zeros((n,), jnp.int32),
            "cache": M.init_cache(cfg, n, steps + 1, policy.compute_dtype),
            "reward": jnp.zeros((n,), jnp.float32),
            # Lineage log-prob: cumulative reward along the surviving
            # ancestry (travels through resampling gathers), since the
            # engine renormalizes the filter weights every step.
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        logits, cache = decode(
            params, p["tok"], step.astype(jnp.int32), p["cache"]
        )
        logits = logits.astype(jnp.float32)
        if temperature > 0:
            tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        logp = jax.nn.log_softmax(logits, -1)
        reward = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        # Freed slots keep ticking past their request's budget ("the bank
        # steps every tick"); clamp the history index so their writes land
        # on the last column instead of out of bounds.
        pos = jnp.minimum(step, steps - 1)
        return {
            "tok": tok,
            "cache": cache,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    def gather(p, anc):
        n = p["tok"].shape[0]
        take = lambda x: jnp.take(x, anc, axis=_batch_axis(x, n))  # noqa: E731
        return {
            "tok": jnp.take(p["tok"], anc, axis=0),
            "cache": jax.tree.map(take, p["cache"]),
            "reward": jnp.take(p["reward"], anc, axis=0),
            "cum_reward": jnp.take(p["cum_reward"], anc, axis=0),
            "seq": jnp.take(p["seq"], anc, axis=0),
        }

    def summary(p, w):
        return {"reward": jnp.sum(w * p["reward"].astype(w.dtype))}

    return SMCSpec(init, transition, loglik, gather=gather, summary=summary)


def run_continuous_batching(
    bank,
    *,
    num_requests: int,
    max_steps: int,
    particles: int,
    key: jax.Array,
    arrival_every: int = 1,
    min_steps: int | None = None,
) -> dict:
    """Admit → step → retire loop over a FilterBank of decode slots.

    Requests arrive on a fixed schedule (request ``i`` at tick
    ``i * arrival_every``) with budgets in [min_steps, max_steps].  A free
    slot is claimed by ``reset_slot`` (traced slot index — no recompile);
    the whole bank steps every tick whether or not every slot holds a
    request; a slot retires the moment its step counter reaches its
    request's budget, returning the highest-cumulative-reward particle's
    sequence.  Returns per-request results plus occupancy/latency stats.
    """
    nb = bank.num_slots
    if min_steps is None:
        min_steps = max(1, max_steps // 2)
    if not 0 <= min_steps <= max_steps:
        raise ValueError(f"need 0 <= min_steps <= max_steps, got "
                         f"{min_steps} > {max_steps}")
    lengths = np.random.default_rng(0).integers(
        min_steps, max_steps + 1, num_requests
    )
    pending = collections.deque(
        {"id": i, "steps": int(lengths[i]), "arrival": i * arrival_every}
        for i in range(num_requests)
    )
    k_state, k_admit, k_run = jax.random.split(key, 3)
    state = bank.init(k_state, particles)
    obs = jnp.zeros((nb,), jnp.int32)  # the decode spec ignores observations
    step = bank.jit_step
    reset = bank.jit_init_slot
    active: dict[int, dict] = {}
    free = list(range(nb))[::-1]
    results, tick, busy_slot_ticks = [], 0, 0
    while pending or active:
        while free and pending and pending[0]["arrival"] <= tick:
            req = pending.popleft()
            slot = free.pop()
            state = reset(
                state,
                jnp.int32(slot),
                jax.random.fold_in(k_admit, req["id"]),
            )
            req["admitted_tick"] = tick
            active[slot] = req
        keys = jax.random.split(jax.random.fold_in(k_run, tick), nb)
        state, _ = step(state, obs, keys)
        tick += 1
        busy_slot_ticks += len(active)
        if active:
            steps_now = np.asarray(state.step)
            done = [s for s in active if steps_now[s] >= active[s]["steps"]]
            if done:
                cum = np.asarray(
                    state.particles["cum_reward"], np.float32
                )
                seqs = np.asarray(state.particles["seq"])
                for slot in done:
                    req = active.pop(slot)
                    best = int(np.argmax(cum[slot]))
                    results.append(
                        {
                            "id": req["id"],
                            "steps": req["steps"],
                            "tokens": seqs[slot, best, : req["steps"]],
                            "admitted_tick": req["admitted_tick"],
                            "finished_tick": tick,
                        }
                    )
                    free.append(slot)
    results.sort(key=lambda r: r["id"])
    return {
        "results": results,
        "ticks": tick,
        "busy_slot_ticks": busy_slot_ticks,
        "occupancy": busy_slot_ticks / max(1, tick * nb),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--smc", action="store_true",
                    help="particle-filter sampling, continuous-batched "
                         "(one FilterBank slot per request)")
    ap.add_argument("--slots", type=int, default=4,
                    help="--smc: concurrent request slots in the bank")
    ap.add_argument("--requests", type=int, default=8,
                    help="--smc: total requests to serve")
    ap.add_argument("--particles-per-slot", type=int, default=4)
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="--smc: ticks between request arrivals")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterBank, FilterConfig
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    policy = get_policy(args.precision)
    b = args.batch
    s_max = args.steps + 1

    params = M.init_params(jax.random.key(1), cfg, policy.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, policy)
    )

    t0 = time.perf_counter()
    if args.smc:
        spec = make_smc_decode_spec(
            params, cfg, policy, decode,
            temperature=args.temperature, steps=args.steps,
        )
        # Engine resampling criterion: ESS < frac * particles, exact
        # comparison (frac >= 1 -> resample every step).
        bank = FilterBank(
            spec,
            FilterConfig(policy=policy, ess_threshold=args.ess_frac),
            num_slots=args.slots,
        )
        stats = run_continuous_batching(
            bank,
            num_requests=args.requests,
            max_steps=args.steps,
            particles=args.particles_per_slot,
            key=jax.random.key(args.seed),
            arrival_every=args.arrival_every,
        )
        dt = time.perf_counter() - t0
        n_steps = sum(r["steps"] for r in stats["results"])
        ticks = max(1, stats["ticks"])
        print(
            f"arch={cfg.name} smc slots={args.slots} "
            f"requests={args.requests} particles/slot="
            f"{args.particles_per_slot} ticks={stats['ticks']} "
            f"occupancy={stats['occupancy']:.0%} "
            f"({dt / ticks * 1e3:.1f} ms/tick incl. compile, "
            f"{n_steps / dt:.1f} request-steps/s)"
        )
        for r in stats["results"][:4]:
            print(
                f"  req[{r['id']}] steps={r['steps']} "
                f"latency={r['finished_tick'] - r['admitted_tick']} ticks: "
                f"{r['tokens'][:12].tolist()}..."
            )
        return
    cache = M.init_cache(cfg, b, s_max, policy.compute_dtype)
    tok = jnp.zeros((b,), jnp.int32)
    seqs = np.zeros((b, args.steps), np.int32)
    key = jax.random.key(args.seed)
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        key, k1 = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                k1, logits / args.temperature, -1
            )
        else:
            tok = jnp.argmax(logits, -1)
        seqs[:, i] = np.asarray(tok)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} independent batch={b} steps={args.steps} "
          f"({dt / args.steps * 1e3:.1f} ms/step incl. compile)")
    for row in range(min(b, 4)):
        print(f"  seq[{row}]: {seqs[row, :16].tolist()}...")


def _batch_axis(x, n):
    for i, d in enumerate(x.shape):
        if d == n:
            return i
    raise ValueError(f"no batch axis in {x.shape}")


if __name__ == "__main__":
    main()
