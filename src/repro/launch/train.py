"""Production training driver: --arch config -> sharded train loop.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b \
        --reduce --steps 50 [--precision bf16_mixed] [--ckpt-dir DIR]

On real hardware this runs the full config over the production mesh; in
this CPU container use ``--reduce`` for a family-faithful small model (the
same code path end to end: sharded params, microbatched AdamW, async
checkpoints, auto-resume).  The 512-device dry-run of the *full* configs
lives in ``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    from repro.checkpoint import Checkpointer
    from repro.configs import get_config, reduced_config
    from repro.core.precision import get_policy
    from repro.data.tokens import BatchSpec, make_batch
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.models.params import TRAIN_RULES, tree_shardings
    from repro.optim import init_opt_state
    from repro.train import TrainConfig, make_train_step

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced_config(cfg)
    policy = get_policy(args.precision)
    mesh = make_local_mesh(("data", "model"))
    compat.set_mesh(mesh)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={mesh.devices.size} policy={policy.name}")

    tcfg = TrainConfig(
        microbatches=args.microbatches, peak_lr=args.peak_lr,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
    )
    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    p_shard = tree_shardings(mesh, M.param_specs(cfg), TRAIN_RULES)
    params = jax.device_put(params, p_shard)
    opt = init_opt_state(params, tcfg.opt)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and ck.latest_step() is not None:
        restored, extra = ck.restore(
            ck.latest_step(), {"params": params, "opt": opt}
        )
        params, opt, start = restored["params"], restored["opt"], extra["next_step"]
        print(f"resumed at step {start}")

    step_fn = jax.jit(make_train_step(cfg, policy, tcfg), donate_argnums=(0, 1))
    spec = BatchSpec("train", args.batch, args.seq)
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = make_batch(cfg, spec, args.seed, i)
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        if i % 10 == 0 or i == args.steps - 1:
            dt = (time.perf_counter() - t0) / max(i - start + 1, 1)
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} ({dt:.2f}s/step)")
        if ck and (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt},
                    extra={"next_step": i + 1}, blocking=False)
    if ck:
        ck.wait()
        ck.save(args.steps, {"params": params, "opt": opt},
                extra={"next_step": args.steps})
    print("done")


if __name__ == "__main__":
    main()
