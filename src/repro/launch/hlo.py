"""HLO-text analysis: collective bytes, op mix (the dry-run 'profiler').

``collective_stats`` parses ``compiled.as_text()`` and attributes wire
bytes per collective kind with ring-algorithm factors:

    all-reduce      2·(n-1)/n · bytes     (reduce-scatter + all-gather)
    all-gather        (n-1)/n · output bytes
    reduce-scatter    (n-1)/n · input bytes
    all-to-all        (n-1)/n · bytes
    collective-permute        1 · bytes

Group size n comes from ``replica_groups={{...}}`` or the iota form
``replica_groups=[G,N]<=[...]``.  Byte counts use the op *result* shapes
(per-device shards in SPMD-partitioned HLO).
"""

from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

__all__ = ["collective_stats", "op_mix", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, num_devices: int) -> dict:
    """Per-kind wire-byte totals (per device) + op counts."""
    bytes_by_kind: dict[str, float] = defaultdict(float)
    count_by_kind: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # "%name = TYPE op-name(..." — TYPE may be a tuple and may carry
        # layout suffixes like {1,0}
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)\(",
            stripped,
        )
        if not m:
            continue
        op = m.group(2)
        kind = next(
            (c for c in _COLLECTIVES if op == c or op.startswith(c + "-")),
            None,
        )
        if kind is None:
            continue
        if op.endswith("-start") is False and ("-done" in op):
            continue  # count the -start, skip the -done
        size = _shape_bytes(m.group(1))
        n = _group_size(stripped, num_devices)
        if n <= 1:
            continue
        ring = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": (n - 1) / n,
            "reduce-scatter": (n - 1) / n,
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[kind]
        bytes_by_kind[kind] += size * ring
        count_by_kind[kind] += 1
    return {
        "wire_bytes": dict(bytes_by_kind),
        "counts": dict(count_by_kind),
        "total_wire_bytes": float(sum(bytes_by_kind.values())),
    }


_OPS_OF_INTEREST = (
    "convert", "exponential", "rsqrt", "divide", "dot", "fusion",
    "dynamic-slice", "dynamic-update-slice", "reduce", "transpose",
    "gather", "scatter",
)


def op_mix(hlo_text: str) -> dict:
    """Counts of selected op kinds — the structural stand-in for the
    paper's Nsight pipeline-utilization evidence (XU ≈ convert/rsqrt)."""
    counts = {k: 0 for k in _OPS_OF_INTEREST}
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+)\s+([\w-]+)\(",
            line,
        )
        if not m:
            continue
        op = m.group(1)
        for k in _OPS_OF_INTEREST:
            if op == k or op.startswith(k + "."):
                counts[k] += 1
    return counts
