import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()

"""Dry-run of the paper's own workload at fleet scale.

Compiles the distributed particle filter step (exact + local resampling
schemes) for 2^25 particles on the single-pod and multi-pod production
meshes — 512x the paper's 64k-particle cap — and records cost/collective
stats.  The comparison of exact-vs-local wire bytes is the §Perf
collective-term iteration for the paper's own technique.

    PYTHONPATH=src python -m repro.launch.pf_dryrun
"""

import json
import time

import jax
import jax.numpy as jnp

from repro.launch.hlo import collective_stats
from repro.launch.mesh import make_production_mesh

ART = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")
)


def run(num_particles: int = 1 << 25, frame: int = 512) -> list[dict]:
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import FilterConfig, ParticleFilter, get_policy
    from repro.core.filter import FilterState
    from repro.core.tracking import TrackerConfig, make_tracker_spec

    out = []
    for mesh_kind in ["single", "multi"]:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        compat.set_mesh(mesh)
        axes = tuple(mesh.axis_names)  # shard particles over the full mesh
        pol = get_policy("bf16_mixed")
        tcfg = TrackerConfig(
            num_particles=num_particles, height=frame, width=frame
        )
        spec = make_tracker_spec(tcfg, pol)
        for scheme in ["exact", "local"]:
            flt = ParticleFilter(
                spec,
                FilterConfig(policy=pol, mesh=mesh, axis=axes, scheme=scheme),
            )
            sh = jax.NamedSharding(mesh, P(axes))
            rep = jax.NamedSharding(mesh, P())
            state_struct = FilterState(
                particles={
                    "pos": jax.ShapeDtypeStruct(
                        (num_particles, 2), pol.compute_dtype
                    )
                },
                log_weights=jax.ShapeDtypeStruct(
                    (num_particles,), pol.compute_dtype
                ),
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            args = (
                state_struct,
                jax.ShapeDtypeStruct((frame, frame), jnp.float32),
                jax.eval_shape(lambda: jax.random.key(0)),
            )
            t0 = time.time()
            jf = jax.jit(
                flt.step,
                in_shardings=(
                    FilterState({"pos": sh}, sh, rep),
                    rep,
                    rep,
                ),
            )
            lowered = jf.lower(*args)
            compiled = lowered.compile()
            ca = compat.cost_analysis(compiled)
            coll = collective_stats(compiled.as_text(), mesh.devices.size)
            rec = dict(
                arch="rodinia-pf",
                shape=f"pf_{num_particles >> 20}m_{scheme}",
                mesh=mesh_kind,
                status="ok",
                devices=int(mesh.devices.size),
                compile_s=round(time.time() - t0, 2),
                flops_per_device=float(ca.get("flops", -1)),
                bytes_per_device=float(ca.get("bytes accessed", -1)),
                collectives=coll,
                particles=num_particles,
            )
            out.append(rec)
            os.makedirs(ART, exist_ok=True)
            with open(
                os.path.join(ART, f"rodinia-pf__{rec['shape']}__{mesh_kind}.json"),
                "w",
            ) as f:
                json.dump(rec, f, indent=1)
            print(
                f"[pf-dryrun] {mesh_kind}/{scheme}: ok compile={rec['compile_s']}s "
                f"flops/dev={rec['flops_per_device']:.3e} "
                f"wire/dev={coll['total_wire_bytes']:.3e}B "
                f"({coll['counts']})"
            )
    return out


if __name__ == "__main__":
    run()
