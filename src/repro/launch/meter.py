"""HLO cost metering with correct scan trip totals.

``compiled.cost_analysis()`` counts a ``lax.scan`` body **once** (verified
empirically in this container), so the production step's numbers undercount
by the trip counts of the layer/microbatch/chunk scans.  This module
recovers exact totals while staying HLO-derived:

1. compile small *fully-unrolled* variants (``unroll_scans=True``,
   reduced ``num_layers`` k, ``microbatches=1``) at several sequence
   points — every op is visible, costs are exact for those variants;
2. layer decomposition: cost(k layers) is affine in k, so
   ``unit = f(k_unit) - f(0)`` and ``overhead = f(0)`` are exact;
3. sequence extrapolation: every per-layer/overhead cost term is a
   polynomial of degree <= 2 in S (attention quadratic, everything else
   linear), so a 3-point fit evaluates exactly at the target S;
4. microbatch scaling: total = microbatches x body (+optimizer once, an
   analytically-estimated ~20 flops/param/device correction).

Metered quantities: flops/device, bytes-accessed/device, collective wire
bytes/device (from HLO text with ring factors).  All on the single-pod
mesh, matching the roofline table.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any

if __name__ == "__main__":  # set before the first jax import (CLI mode)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("DRYRUN_XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512"
    ).strip()

import jax
import numpy as np

from repro import compat
from repro.configs import SHAPES, get_config
from repro.launch.hlo import collective_stats

__all__ = ["meter_cell", "MeterResult"]


def _poly_fit_eval(xs, ys, x_target, deg):
    coef = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), deg)
    return float(np.polyval(coef, float(x_target)))


def _seq_points(cfg, kind: str, target: int) -> tuple[list[int], int]:
    """(metering sequence points, polynomial degree)."""
    if kind == "decode":
        # decode cost is affine in cache length; ring-buffered local layers
        # saturate at window, so points sit above the window.
        lo = max(2048, 2 * (cfg.window or 0))
        return [lo, 2 * lo], 1
    # full-sequence: quadratic (attention); points above the local window
    # and divisible by every chunk size in play.  Chunk-scan archs
    # (ssm/rwkv) unroll S/chunk bodies per layer when metered — keep their
    # points small (costs stay polynomial in S, the fit is still exact).
    lo = max(1024, 2 * (cfg.window or 0))
    if cfg.ssm_state or cfg.is_rwkv:
        lo = 256
    pts = [lo, 2 * lo, 4 * lo]
    # attention-free stacks are *linear* in S; a quadratic fit amplifies
    # point noise ~ (S_target/S_max)^2 at long-context extrapolation
    # (measured 16x bytes inflation on rwkv prefill_32k)
    deg = 1 if cfg.is_rwkv else 2
    return ([min(p, target) for p in pts] if target < 4 * lo else pts), deg


def _layer_points(cfg) -> tuple[list[int], dict[str, Any]]:
    """k values to compile + how to compose f(target L) from them."""
    if cfg.global_every:
        g = cfg.global_every
        n_units, n_tail = divmod(cfg.num_layers, g)
        ks = [0, g] + ([n_tail] if n_tail else [])

        def compose(f):
            unit = f[g] - f[0]
            tail = (f[n_tail] - f[0]) if n_tail else 0.0
            return f[0] + n_units * unit + tail

        return ks, compose
    if cfg.attn_every:
        e = cfg.attn_every
        n_units = cfg.num_layers // e

        def compose(f):
            return f[0] + n_units * (f[e] - f[0])

        return [0, e], compose

    def compose(f):
        return f[0] + cfg.num_layers * (f[1] - f[0])

    return [0, 1], compose


class MeterResult(dict):
    pass


def meter_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    policy_name: str = "bf16_mixed",
    serve_rules=None,
    cache_dir: str | None = None,
    tag: str = "",
    verbose: bool = True,
    train_micro: int | None = None,
    extra_cfg: dict | None = None,
    shard_logits: bool = False,
) -> MeterResult:
    """Exact-trip-count metering of one cell on ``mesh``."""
    from repro.launch.specs import TRAIN_MICRO, build_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    n_micro = (train_micro or TRAIN_MICRO) if kind == "train" else 1

    cache_path = None
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        cache_path = os.path.join(
            cache_dir, f"{arch}__{shape_name}__meter{('__'+tag) if tag else ''}.json"
        )
        if os.path.exists(cache_path):
            with open(cache_path) as f:
                return MeterResult(json.load(f))

    compat.set_mesh(mesh)  # context mesh: enables in-model sharding hints
    seq_pts, deg = _seq_points(cfg, kind, shape.seq_len)
    seq_pts = sorted(set(seq_pts))
    if len(seq_pts) <= deg:
        deg = len(seq_pts) - 1
    layer_ks, compose = _layer_points(cfg)

    # batch: per-microbatch global batch for train; target batch otherwise
    batch = (
        shape.global_batch // n_micro if kind == "train" else shape.global_batch
    )

    metrics = ("flops", "bytes", "wire")
    grid: dict[tuple[int, int], dict[str, float]] = {}
    n_dev = mesh.devices.size
    for s_pt in seq_pts:
        for k in layer_ks:
            t0 = time.time()
            cell = build_cell(
                arch,
                shape_name,
                mesh,
                policy_name=policy_name,
                serve_rules=serve_rules,
                train_micro=1,
                cfg_overrides=dict(
                    num_layers=k, unroll_scans=True, **(extra_cfg or {})
                ),
                seq_override=s_pt,
                batch_override=batch,
                shard_logits=shard_logits,
            )
            compiled = (
                jax.jit(
                    cell["fn"],
                    in_shardings=cell["in_shardings"],
                    out_shardings=cell["out_shardings"],
                    donate_argnums=cell["donate"],
                )
                .lower(*cell["args"])
                .compile()
            )
            ca = compat.cost_analysis(compiled)
            coll = collective_stats(compiled.as_text(), n_dev)
            grid[(s_pt, k)] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": coll["total_wire_bytes"],
            }
            if verbose:
                print(
                    f"[meter] {arch}/{shape_name} S={s_pt} k={k}: "
                    f"flops={grid[(s_pt,k)]['flops']:.3e} "
                    f"({time.time()-t0:.1f}s)"
                )

    # compose layers at each S, then fit in S and evaluate at the target.
    out: dict[str, float] = {}
    for m in metrics:
        vals = []
        for s_pt in seq_pts:
            f = {k: grid[(s_pt, k)][m] for k in layer_ks}
            vals.append(compose(f))
        total_body = (
            _poly_fit_eval(seq_pts, vals, shape.seq_len, deg)
            if len(seq_pts) > 1
            else vals[0]
        )
        if kind == "train" and m == "flops":
            # microbatch scaling with optimizer-once correction
            n_params = cfg.param_count()
            opt_flops = 20.0 * n_params / n_dev
            out[m] = n_micro * max(total_body - opt_flops, 0.0) + opt_flops
        elif kind == "train":
            # ~24 B/param/device once per step: params fp32 r/w, m bf16 r/w,
            # v fp32 r/w, grad read
            n_params = cfg.param_count()
            opt_bytes = 24.0 * n_params / n_dev if m == "bytes" else 0.0
            out[m] = n_micro * max(total_body - opt_bytes, 0.0) + opt_bytes
        else:
            out[m] = total_body

    result = MeterResult(
        arch=arch,
        shape=shape_name,
        devices=n_dev,
        seq_points=seq_pts,
        layer_points=layer_ks,
        flops_per_device=out["flops"],
        bytes_per_device=out["bytes"],
        wire_bytes_per_device=out["wire"],
        microbatches=n_micro,
        grid={f"{s}_{k}": v for (s, k), v in grid.items()},
    )
    if cache_path:
        with open(cache_path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    import argparse

    from repro.configs import SHAPES, list_archs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_skip_reason

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--policy", default="bf16_mixed")
    args = ap.parse_args()
    out_dir = args.out or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "../../../artifacts/meter")
    )
    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    mesh = make_production_mesh()
    for arch in archs:
        for shape in shapes:
            if cell_skip_reason(arch, shape):
                continue
            try:
                t0 = time.time()
                r = meter_cell(
                    arch, shape, mesh, cache_dir=out_dir, verbose=False,
                    tag=args.tag, policy_name=args.policy,
                )
                print(
                    f"[meter] {arch}/{shape}: flops/dev={r['flops_per_device']:.3e} "
                    f"wire/dev={r['wire_bytes_per_device']:.3e} "
                    f"({time.time()-t0:.0f}s)", flush=True,
                )
            except Exception as e:  # noqa: BLE001
                import traceback

                print(f"[meter] {arch}/{shape}: ERROR {type(e).__name__}: {e}")
                traceback.print_exc()


if __name__ == "__main__":
    main()
