"""Roofline analysis from the dry-run + metering artifacts.

Three terms per (arch x shape) cell, single-pod mesh (256 chips), TPU v5e
constants:

    compute    = flops_per_device / peak_flops        [197e12 bf16]
    memory     = bytes_per_device / hbm_bw            [819e9 B/s]
    collective = wire_bytes_per_device / link_bw      [50e9 B/s]

flops/bytes/wire come from the *metering* artifacts (exact scan-trip
totals — see launch.meter); the production compile supplies
memory_analysis (fits-on-chip proof) and the collective schedule.

Also reported: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE; decode
counts D = batch tokens), the useful-compute ratio MODEL_FLOPS /
(flops_per_device * chips), the dominant term, and a one-line lever.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any

from repro.configs import SHAPES, get_config, list_archs

__all__ = [
    "load_cell",
    "roofline_row",
    "build_table",
    "render_markdown",
    "epilogue_rows",
    "render_epilogue_markdown",
    "step_rows",
    "render_step_markdown",
]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link (conservative single-link model)

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../../artifacts"))


def _read(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def load_cell(arch: str, shape: str, art_dir: str = ART, tag: str = "") -> dict | None:
    sfx = f"__{tag}" if tag else ""
    prod = _read(os.path.join(art_dir, "dryrun", f"{arch}__{shape}__single{sfx}.json"))
    meter = _read(os.path.join(art_dir, "meter", f"{arch}__{shape}__meter{sfx}.json"))
    if prod is None:
        return None
    return {"prod": prod, "meter": meter}


def _model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_row(arch: str, shape: str, art_dir: str = ART, tag: str = "") -> dict | None:
    cell = load_cell(arch, shape, art_dir, tag)
    if cell is None:
        return None
    prod = cell["prod"]
    if prod.get("status") == "skip":
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": prod["reason"]}
    if prod.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": "error",
                "reason": prod.get("error", "?")[:120]}
    meter = cell["meter"]
    if meter is None:
        return {"arch": arch, "shape": shape, "status": "no-meter"}

    chips = prod["devices"]
    flops = meter["flops_per_device"]
    bytes_ = meter["bytes_per_device"]
    wire = meter["wire_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = wire / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = _model_flops(arch, shape)
    ratio = mf / (flops * chips) if flops > 0 else 0.0
    # roofline fraction: useful model compute per chip-second at the bound
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0

    lever = {
        "compute": "reduce recompute (remat policy) / raise useful-FLOP ratio",
        "memory": "fuse reads, shrink activation dtype, raise arithmetic intensity per HBM byte",
        "collective": "reshard to cut gather/reduce volume or overlap with compute",
    }[dom]
    return {
        "arch": arch,
        "shape": shape,
        "status": "ok",
        "chips": chips,
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "lever": lever,
        "memory_per_device": prod.get("memory", {}),
        "compile_s": prod.get("compile_s"),
    }


def build_table(art_dir: str = ART, tag: str = "") -> list[dict]:
    rows = []
    for arch in list_archs():
        for shape in SHAPES:
            row = roofline_row(arch, shape, art_dir, tag)
            if row is not None:
                rows.append(row)
    return rows


def render_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "6ND/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} "
                f"| — | — | {r.get('reason','')} |"
            )
            continue
        out.append(
            "| {arch} | {shape} | {c:.2e} | {m:.2e} | {x:.2e} | {dom} | "
            "{ratio:.2f} | {frac:.1%} | {lever} |".format(
                arch=r["arch"], shape=r["shape"], c=r["compute_s"],
                m=r["memory_s"], x=r["collective_s"], dom=r["dominant"],
                ratio=r["useful_ratio"], frac=r["roofline_frac"],
                lever=r["lever"],
            )
        )
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Weight-epilogue traffic model: HBM bytes per particle-step of the fig5
# weight pipeline (normalize → ESS → CDF → search → ancestor read), before
# and after fusion.  Particle-state gather traffic is identical across
# variants and excluded.  Per particle, with c = compute-dtype bytes:
#
#   composed (pre-PR):  normalize reads log_w twice + writes w (3c), ESS
#                       re-reads w (c), cumsum reads w + writes fp32 CDF
#                       (c + 4), search reads CDF + writes int32 ancestors
#                       (4 + 4), gather reads ancestors (4)   = 5c + 16
#   composed (+stats):  ESS sums accumulate inside the normalize pass, the
#                       separate w read disappears                = 4c + 16
#   fused epilogue:     log_w read twice, w written once, ancestors
#                       written + read back; CDF stays in VMEM    = 3c + 8
#
# The fused path materializes the (B, P) weight array exactly once.

_EPILOGUE_DTYPE_BYTES = {"fp64": 8, "fp32": 4, "bf16": 2, "fp16": 2}


def epilogue_rows(particles: int = 65_536) -> list[dict]:
    """Per-policy epilogue traffic: bytes/particle-step and the projected
    HBM-bound step time at ``particles``, composed vs fused.  Attaches the
    measured speedup from BENCH_fused.json when one is present — at the
    exact ``particles`` when the sweep recorded it, else at the largest
    size the sweep did record (the smoke runs 8k/32k while the traffic
    model defaults to the paper's 64k)."""
    measured = {}
    bench = _read(os.path.join(os.getcwd(), "BENCH_fused.json"))
    if bench:
        by_policy: dict[str, list[dict]] = {}
        for r in bench.get("records", []):
            by_policy.setdefault(r["policy"], []).append(r)
        for pol, recs in by_policy.items():
            exact = [r for r in recs if r["particles"] == particles]
            pick = (
                exact[0]
                if exact
                else max(recs, key=lambda r: r["particles"])
            )
            measured[pol] = pick["speedup_fused_vs_composed"]
    rows = []
    for policy, c in _EPILOGUE_DTYPE_BYTES.items():
        composed_pre = 5 * c + 16
        composed = 4 * c + 16
        fused = 3 * c + 8
        rows.append(
            {
                "policy": policy,
                "bytes_per_particle_composed_pre": composed_pre,
                "bytes_per_particle_composed": composed,
                "bytes_per_particle_fused": fused,
                "traffic_ratio_fused_vs_composed": composed / fused,
                "hbm_s_composed": composed * particles / HBM_BW,
                "hbm_s_fused": fused * particles / HBM_BW,
                "measured_speedup": measured.get(policy),
            }
        )
    return rows


def render_epilogue_markdown(rows: list[dict]) -> str:
    out = [
        "| policy | B/particle composed(pre) | composed(+stats) | fused | "
        "traffic ratio | HBM s/step composed | fused | measured speedup |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        meas = (
            f"{r['measured_speedup']:.2f}x"
            if r["measured_speedup"] is not None
            else "—"
        )
        out.append(
            "| {p} | {pre} | {c} | {f} | {ratio:.2f}x | {hc:.2e} | "
            "{hf:.2e} | {meas} |".format(
                p=r["policy"],
                pre=r["bytes_per_particle_composed_pre"],
                c=r["bytes_per_particle_composed"],
                f=r["bytes_per_particle_fused"],
                ratio=r["traffic_ratio_fused_vs_composed"],
                hc=r["hbm_s_composed"],
                hf=r["hbm_s_fused"],
                meas=meas,
            )
        )
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Full-step traffic model: HBM bytes per particle-step of the whole
# likelihood → weights → resample chain (the fig6 kernel sequence),
# composed vs the fused step kernel.  The XLA patch gather *writes* J·c
# bytes of patches in every variant (identical, excluded, like particle
# state); what differs is everything after the patch matrix exists.  Per
# particle, with c = compute-dtype bytes and J = disk points:
#
#   composed (pre-PR):   likelihood reads patches + writes log-lik
#                        (J·c + c), the weight add re-reads it and writes
#                        log_w (2c), then the pre-fusion normalize chain
#                        (4c + 16 — see the epilogue model)  = J·c + 7c + 16
#   composed (+fused     likelihood (J·c + c) + weight add (2c) + the
#    epilogue):          fused epilogue (3c + 8)             = J·c + 6c + 8
#   fused step:          one pass reads patches (J·c), writes w (c), and
#                        writes + reads back ancestors (8); the log-lik
#                        and log-weight arrays never touch HBM = J·c + c + 8
#
# The fused saving is exactly the 5c of intermediate log-lik/log-weight
# traffic the composed chain round-trips between kernels.

_DEFAULT_DISK_POINTS = 69  # radius-4 disk, the paper's template


def step_rows(particles: int = 65_536, points: int = _DEFAULT_DISK_POINTS) -> list[dict]:
    """Per-policy full-step traffic: bytes/particle-step and the projected
    HBM-bound step time at ``particles``, composed chain vs the fused step
    kernel.  Attaches the measured speedup from BENCH_fig6.json when one is
    present (exact ``particles`` when recorded, else the largest size the
    sweep recorded), mirroring the ``--epilogue`` wiring."""
    measured = {}
    bench = _read(os.path.join(os.getcwd(), "BENCH_fig6.json"))
    if bench:
        by_policy: dict[str, list[dict]] = {}
        for r in bench.get("records", []):
            by_policy.setdefault(r["policy"], []).append(r)
        for pol, recs in by_policy.items():
            exact = [r for r in recs if r["particles"] == particles]
            pick = (
                exact[0]
                if exact
                else max(recs, key=lambda r: r["particles"])
            )
            measured[pol] = pick["speedup_fused_vs_composed"]
    rows = []
    for policy, c in _EPILOGUE_DTYPE_BYTES.items():
        patch = points * c
        composed_pre = patch + 7 * c + 16
        composed = patch + 6 * c + 8
        fused = patch + c + 8
        rows.append(
            {
                "policy": policy,
                "disk_points": points,
                "bytes_per_particle_composed_pre": composed_pre,
                "bytes_per_particle_composed": composed,
                "bytes_per_particle_fused": fused,
                "traffic_ratio_fused_vs_composed": composed / fused,
                "hbm_s_composed": composed * particles / HBM_BW,
                "hbm_s_fused": fused * particles / HBM_BW,
                "measured_speedup": measured.get(policy),
            }
        )
    return rows


def render_step_markdown(rows: list[dict]) -> str:
    out = [
        "| policy | B/particle composed(pre) | composed(+epilogue) | fused "
        "step | traffic ratio | HBM s/step composed | fused | measured "
        "speedup |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        meas = (
            f"{r['measured_speedup']:.2f}x"
            if r["measured_speedup"] is not None
            else "—"
        )
        out.append(
            "| {p} | {pre} | {c} | {f} | {ratio:.2f}x | {hc:.2e} | "
            "{hf:.2e} | {meas} |".format(
                p=r["policy"],
                pre=r["bytes_per_particle_composed_pre"],
                c=r["bytes_per_particle_composed"],
                f=r["bytes_per_particle_fused"],
                ratio=r["traffic_ratio_fused_vs_composed"],
                hc=r["hbm_s_composed"],
                hf=r["hbm_s_fused"],
                meas=meas,
            )
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default=ART)
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", default=None)
    ap.add_argument(
        "--epilogue",
        action="store_true",
        help="print the weight-epilogue HBM traffic table (bytes per "
        "particle-step, composed vs fused) instead of the arch table",
    )
    ap.add_argument(
        "--step",
        action="store_true",
        help="print the full-step HBM traffic table (patch reads + "
        "likelihood + weights per particle-step, composed chain vs the "
        "fused step kernel) instead of the arch table",
    )
    ap.add_argument("--particles", type=int, default=65_536)
    ap.add_argument(
        "--points",
        type=int,
        default=_DEFAULT_DISK_POINTS,
        help="disk points J per patch for --step (radius-4 default)",
    )
    args = ap.parse_args()
    if args.epilogue:
        rows = epilogue_rows(args.particles)
        print(render_epilogue_markdown(rows))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1)
        return
    if args.step:
        rows = step_rows(args.particles, args.points)
        print(render_step_markdown(rows))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1)
        return
    rows = build_table(args.art, args.tag)
    print(render_markdown(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
