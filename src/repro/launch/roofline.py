"""Roofline analysis from the dry-run + metering artifacts.

Three terms per (arch x shape) cell, single-pod mesh (256 chips), TPU v5e
constants:

    compute    = flops_per_device / peak_flops        [197e12 bf16]
    memory     = bytes_per_device / hbm_bw            [819e9 B/s]
    collective = wire_bytes_per_device / link_bw      [50e9 B/s]

flops/bytes/wire come from the *metering* artifacts (exact scan-trip
totals — see launch.meter); the production compile supplies
memory_analysis (fits-on-chip proof) and the collective schedule.

Also reported: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE; decode
counts D = batch tokens), the useful-compute ratio MODEL_FLOPS /
(flops_per_device * chips), the dominant term, and a one-line lever.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any

from repro.configs import SHAPES, get_config, list_archs

__all__ = ["load_cell", "roofline_row", "build_table", "render_markdown"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link (conservative single-link model)

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../../artifacts"))


def _read(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def load_cell(arch: str, shape: str, art_dir: str = ART, tag: str = "") -> dict | None:
    sfx = f"__{tag}" if tag else ""
    prod = _read(os.path.join(art_dir, "dryrun", f"{arch}__{shape}__single{sfx}.json"))
    meter = _read(os.path.join(art_dir, "meter", f"{arch}__{shape}__meter{sfx}.json"))
    if prod is None:
        return None
    return {"prod": prod, "meter": meter}


def _model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_row(arch: str, shape: str, art_dir: str = ART, tag: str = "") -> dict | None:
    cell = load_cell(arch, shape, art_dir, tag)
    if cell is None:
        return None
    prod = cell["prod"]
    if prod.get("status") == "skip":
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": prod["reason"]}
    if prod.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": "error",
                "reason": prod.get("error", "?")[:120]}
    meter = cell["meter"]
    if meter is None:
        return {"arch": arch, "shape": shape, "status": "no-meter"}

    chips = prod["devices"]
    flops = meter["flops_per_device"]
    bytes_ = meter["bytes_per_device"]
    wire = meter["wire_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = wire / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = _model_flops(arch, shape)
    ratio = mf / (flops * chips) if flops > 0 else 0.0
    # roofline fraction: useful model compute per chip-second at the bound
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0

    lever = {
        "compute": "reduce recompute (remat policy) / raise useful-FLOP ratio",
        "memory": "fuse reads, shrink activation dtype, raise arithmetic intensity per HBM byte",
        "collective": "reshard to cut gather/reduce volume or overlap with compute",
    }[dom]
    return {
        "arch": arch,
        "shape": shape,
        "status": "ok",
        "chips": chips,
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "lever": lever,
        "memory_per_device": prod.get("memory", {}),
        "compile_s": prod.get("compile_s"),
    }


def build_table(art_dir: str = ART, tag: str = "") -> list[dict]:
    rows = []
    for arch in list_archs():
        for shape in SHAPES:
            row = roofline_row(arch, shape, art_dir, tag)
            if row is not None:
                rows.append(row)
    return rows


def render_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "6ND/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} "
                f"| — | — | {r.get('reason','')} |"
            )
            continue
        out.append(
            "| {arch} | {shape} | {c:.2e} | {m:.2e} | {x:.2e} | {dom} | "
            "{ratio:.2f} | {frac:.1%} | {lever} |".format(
                arch=r["arch"], shape=r["shape"], c=r["compute_s"],
                m=r["memory_s"], x=r["collective_s"], dom=r["dominant"],
                ratio=r["useful_ratio"], frac=r["roofline_frac"],
                lever=r["lever"],
            )
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default=ART)
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = build_table(args.art, args.tag)
    print(render_markdown(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
