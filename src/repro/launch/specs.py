"""Per-cell step builders + ShapeDtypeStruct input specs + shardings.

``build_cell(arch, shape, mesh)`` returns (fn, args_structs, in_shardings,
out_shardings) ready for ``jax.jit(fn, ...).lower(*args).compile()`` — used
by the dry-run, the roofline analysis, and the perf iterations.

Skip policy (see DESIGN.md §Arch-applicability):
- ``long_500k`` only for sub-quadratic stacks (gemma3 local:global, zamba2,
  rwkv6);
- decode shapes skipped for encoder-only (hubert).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core.precision import get_policy
from repro.data.tokens import BatchSpec, batch_structs
from repro.models import model as M
from repro.models.params import (
    SERVE_RULES,
    TRAIN_RULES,
    ParamSpec,
    abstract_params,
    logical_to_spec,
    tree_shardings,
)
from repro.optim.adamw import OptConfig, opt_state_specs
from repro.train.train_loop import TrainConfig, make_train_step

__all__ = ["build_cell", "cell_skip_reason", "SUBQUADRATIC", "all_cells"]

SUBQUADRATIC = {"gemma3-27b", "zamba2-2.7b", "rwkv6-7b"}

# Train microbatch counts: global batch 256 -> 8 microbatches of 32 keeps
# the largest (per-micro) logit buffer ~seq*vocab/model_shards*2B per device
# and divides the 32-way batch sharding of the multi-pod mesh.
TRAIN_MICRO = 8


def cell_skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only: no decode step"
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full attention: 500k decode needs sub-quadratic stack"
    return None


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import list_archs

    return [
        (a, s)
        for a in list_archs()
        for s in SHAPES
    ]


def _batch_sharding(mesh, structs: dict) -> dict:
    def shard(s: jax.ShapeDtypeStruct):
        axes = ["batch"] + [None] * (len(s.shape) - 1)
        spec = logical_to_spec(mesh, s.shape, tuple(axes), TRAIN_RULES)
        return NamedSharding(mesh, spec)

    return jax.tree.map(shard, structs)


def build_cell(arch: str, shape_name: str, mesh, *, policy_name: str = "bf16_mixed",
               serve_rules=None, train_micro: int | None = None,
               cfg_overrides: dict | None = None,
               seq_override: int | None = None,
               batch_override: int | None = None,
               shard_logits: bool = True):
    """Returns dict(fn=, args=, in_shardings=, out_shardings=, meta=).

    ``cfg_overrides``/``seq_override``/``batch_override`` support the
    metering compiles (launch.meter): reduced layer counts + unrolled scans
    at several sequence points, from which true trip-total costs are solved.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if seq_override or batch_override:
        shape = dataclasses.replace(
            shape,
            seq_len=seq_override or shape.seq_len,
            global_batch=batch_override or shape.global_batch,
        )
    policy = get_policy(policy_name)
    serve_rules = serve_rules or SERVE_RULES

    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=train_micro or TRAIN_MICRO)
        pspecs = M.param_specs(cfg)
        params = abstract_params(pspecs, jnp.float32)
        p_shard = tree_shardings(mesh, pspecs, TRAIN_RULES)
        ospecs = opt_state_specs(pspecs, tcfg.opt)
        opt = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                tcfg.opt.m_dtype if False else jnp.float32,
            ),
            ospecs,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        # proper dtypes for m/v
        opt = {
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, tcfg.opt.m_dtype),
                ospecs["m"], is_leaf=lambda x: isinstance(x, ParamSpec),
            ),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, tcfg.opt.v_dtype),
                ospecs["v"], is_leaf=lambda x: isinstance(x, ParamSpec),
            ),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        o_shard = {
            "m": tree_shardings(mesh, ospecs["m"], TRAIN_RULES),
            "v": tree_shardings(mesh, ospecs["v"], TRAIN_RULES),
            "step": NamedSharding(mesh, P()),
        }
        bstructs = batch_structs(cfg, BatchSpec("train", shape.global_batch, shape.seq_len))
        b_shard = _batch_sharding(mesh, bstructs)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_train_step(cfg, policy, tcfg)
        return dict(
            fn=fn,
            args=(params, opt, bstructs, step),
            in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
            meta=dict(cfg=cfg, policy=policy, kind="train",
                      tokens=shape.global_batch * shape.seq_len),
            donate=(0, 1),
        )

    # ----- serving cells -----
    pspecs = M.param_specs(cfg)
    params = abstract_params(pspecs, policy.param_dtype)
    p_shard = tree_shardings(mesh, pspecs, serve_rules)

    if shape.kind == "prefill":
        bstructs = batch_structs(
            cfg, BatchSpec("prefill", shape.global_batch, shape.seq_len)
        )
        b_shard = _batch_sharding(mesh, bstructs)

        def prefill_fn(params, batch):
            return M.prefill(params, batch, cfg, policy, shape.seq_len)

        return dict(
            fn=prefill_fn,
            args=(params, bstructs),
            in_shardings=(p_shard, b_shard),
            out_shardings=NamedSharding(mesh, P()),
            meta=dict(cfg=cfg, policy=policy, kind="prefill",
                      tokens=shape.global_batch * shape.seq_len),
            donate=(),
        )

    # decode: one new token against a cache of seq_len.  The cache stores
    # *activations*, so it uses the compute dtype (fp8 weight-only policies
    # keep a bf16 cache); recurrent states stay fp32.
    cspecs = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cache = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jnp.float32 if s.init == "zeros_f32" else policy.compute_dtype,
        ),
        cspecs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    c_shard = tree_shardings(mesh, cspecs, serve_rules)
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_shard = NamedSharding(
        mesh,
        logical_to_spec(mesh, (shape.global_batch,), ("batch",), serve_rules),
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, token, pos, cache):
        return M.decode_step(params, token, pos, cache, cfg, policy)

    # Optimized serving keeps the logits vocab-sharded (the sampler works
    # on shards); the baseline replicates them, which costs a full-vocab
    # all-gather per step.
    logits_spec = (
        logical_to_spec(
            mesh, (shape.global_batch, cfg.vocab_size), ("batch", "vocab"),
            serve_rules,
        )
        if shard_logits
        else P()
    )
    return dict(
        fn=decode_fn,
        args=(params, tok, pos, cache),
        in_shardings=(p_shard, tok_shard, NamedSharding(mesh, P()), c_shard),
        out_shardings=(NamedSharding(mesh, logits_spec), c_shard),
        meta=dict(cfg=cfg, policy=policy, kind="decode",
                  tokens=shape.global_batch),
        donate=(3,),
    )
