"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before the first device query.
"""

from __future__ import annotations

import jax

from repro import compat

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(axes: tuple[str, ...] = ("data",)):
    """All local devices on the first axis (CPU tests, examples)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes)
    )
