import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count at first init.  For every cell this script:

    jit(step).lower(*ShapeDtypeStructs).compile()

on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, then records
``memory_analysis()``, ``cost_analysis()``, and HLO collective stats to
``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.  Resumable: existing
artifacts are skipped unless --force.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both [--force] [--rules serve_v2] [--tag optim]
"""

import argparse
import json
import time
import traceback

import jax

from repro import compat
from repro.launch.hlo import collective_stats, op_mix
from repro.launch.mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             force: bool = False, tag: str = "", **build_kw) -> dict:
    from repro.launch.specs import build_cell, cell_skip_reason

    name = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    record: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag}
    skip = cell_skip_reason(arch, shape)
    if skip:
        record["status"] = "skip"
        record["reason"] = skip
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        compat.set_mesh(mesh)  # context mesh: enables in-model sharding hints
        n_dev = mesh.devices.size
        try:
            t0 = time.time()
            cell = build_cell(arch, shape, mesh, **build_kw)
            jf = jax.jit(
                cell["fn"],
                in_shardings=cell["in_shardings"],
                out_shardings=cell["out_shardings"],
                donate_argnums=cell["donate"],
            )
            lowered = jf.lower(*cell["args"])
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

            ca = compat.cost_analysis(compiled)
            ma = compiled.memory_analysis()
            hlo = compiled.as_text()
            coll = collective_stats(hlo, n_dev)
            record.update(
                status="ok",
                devices=n_dev,
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                flops_per_device=float(ca.get("flops", -1.0)),
                bytes_per_device=float(ca.get("bytes accessed", -1.0)),
                transcendentals=float(ca.get("transcendentals", 0.0)),
                memory=dict(
                    argument_bytes=getattr(ma, "argument_size_in_bytes", None),
                    output_bytes=getattr(ma, "output_size_in_bytes", None),
                    temp_bytes=getattr(ma, "temp_size_in_bytes", None),
                    alias_bytes=getattr(ma, "alias_size_in_bytes", None),
                ),
                collectives=coll,
                op_mix=op_mix(hlo),
                meta=dict(
                    kind=cell["meta"]["kind"],
                    tokens=cell["meta"]["tokens"],
                ),
            )
            print(
                f"[dryrun] {name}: ok  compile={t_compile:.1f}s "
                f"flops/dev={record['flops_per_device']:.3e} "
                f"wire={coll['total_wire_bytes']:.3e}B"
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            record["status"] = "error"
            record["error"] = f"{type(e).__name__}: {e}"
            record["traceback"] = traceback.format_exc()[-4000:]
            print(f"[dryrun] {name}: ERROR {record['error'][:200]}")

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--policy", default="bf16_mixed")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (
        ["single", "multi"] if args.mesh == "both" else [args.mesh]
    )
    out_dir = args.out or os.path.abspath(ARTIFACT_DIR)

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(
                    arch, shape, mesh_kind, out_dir,
                    force=args.force, tag=args.tag,
                    policy_name=args.policy,
                )
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skip"
                n_err += s == "error"
    print(f"[dryrun] done: {n_ok} ok / {n_skip} skip / {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
