"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make_schedule"]


def make_schedule(
    kind: str = "cosine",
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    min_ratio: float = 0.1,
):
    def cosine(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup_steps, warm, decay)

    def constant(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup_steps, 1), 1.0)
        return peak_lr * warm

    return {"cosine": cosine, "constant": constant}[kind]
