"""AdamW with precision-policy-aware state dtypes.

The paper's theme (16-bit where it is safe, wider where it is not) applied
to optimizer state: first moment stores bf16 (its use is a smoothed average
— resilient), second moment and the parameters stay fp32 (``v`` feeds an
rsqrt — 8 mantissa bits there visibly bias the preconditioner; measured in
tests/test_optim.py).  This is what makes grok-1-scale training fit v5e HBM
(see EXPERIMENTS.md §Dry-run).

Update math runs in fp32 regardless of storage dtypes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # analysis: allow(dtype-literal): documented default for first-moment
    # storage; overridable per config, outside the filter policy's scope
    m_dtype: Any = jnp.bfloat16
    v_dtype: Any = jnp.float32


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.m_dtype), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.v_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs: Any, cfg: OptConfig) -> dict:
    """ParamSpec tree for the optimizer state (same shardings as params)."""
    from repro.models.params import ParamSpec

    def clone(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical, init="zeros")

    is_spec = lambda x: isinstance(x, ParamSpec)
    return {
        "m": jax.tree.map(clone, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(clone, param_specs, is_leaf=is_spec),
        "step": ParamSpec((), ()),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Any,
    grads: Any,
    state: dict,
    lr: jax.Array,
    cfg: OptConfig,
) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics).

    Non-finite gradients (fp16 overflow upstream) skip the update entirely
    — the fault-tolerant behaviour for mixed-precision training.
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.where(
        gnorm > cfg.clip_norm, cfg.clip_norm / jnp.maximum(gnorm, 1e-9), 1.0
    )
    scale = jnp.where(finite, scale, 0.0)

    c1 = 1.0 - cfg.b1**step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2**step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        # scrub non-finite entries so a skipped step cannot poison moments
        g = jnp.where(jnp.isfinite(g), g, 0.0) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1.0 - cfg.b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = p.astype(jnp.float32) - lr * delta
        new_p = jnp.where(finite, new_p, p.astype(jnp.float32))
        return (
            new_p.astype(p.dtype),
            m32.astype(m.dtype),
            v32.astype(v.dtype),
        )

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "finite": finite.astype(jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
