"""Optimizer substrate: AdamW, schedules, clipping, compressed collectives."""

from repro.optim.adamw import (  # noqa: F401
    OptConfig,
    adamw_update,
    init_opt_state,
    opt_state_specs,
)
from repro.optim.schedule import make_schedule  # noqa: F401
