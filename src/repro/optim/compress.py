"""Int8 block-quantized gradient all-reduce with error feedback.

The paper's low-precision theme applied to the *wire*: in data-parallel
training the gradient all-reduce moves 4 bytes/param/step; block-quantizing
to int8 (+ one fp32 scale per 256-value block) cuts collective bytes ~3.9x.
Error feedback (Seide et al.) carries the quantization residual into the
next step so the compression bias does not accumulate.

Usage: inside a shard_map'd train step (``train_loop.make_shardmap_step``)
— quantize local grads, ring all-reduce int8 payloads (psum in int32 to
avoid overflow across >=256 shards with randomized per-shard scales kept
separate), dequantize, add residual.  Tested for numeric contract in
tests/test_compress.py and measured in §Perf (collective-term row).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_blockwise",
    "dequantize_blockwise",
    "compressed_psum",
    "error_feedback_init",
]

BLOCK = 256


def _pad_flat(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def quantize_blockwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp -> (int8 values, fp32 per-block scales)."""
    flat, _ = _pad_flat(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_blockwise(
    q: jax.Array, scale: jax.Array, shape: tuple[int, ...], dtype=jnp.float32
) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(
    grads: Any, axis, err: Any
) -> tuple[Any, Any]:
    """All-reduce a gradient pytree in int8 with error feedback.

    Runs inside shard_map.  Each leaf: g+err -> quantize -> all_gather the
    (int8, scales) payloads -> dequantize-and-sum locally -> new residual.
    Wire bytes: ~1.02 bytes/param vs 4 (fp32) / 2 (bf16).
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_blockwise(target)
        local = dequantize_blockwise(q, s, g.shape)
        new_err = target - local
        qs = jax.lax.all_gather(q, axis)      # (D, blocks*BLOCK) int8 wire
        ss = jax.lax.all_gather(s, axis)      # (D, blocks) fp32 scales
        summed = jnp.sum(
            qs.astype(jnp.float32).reshape(qs.shape[0], -1, BLOCK)
            * ss[..., None],
            axis=0,
        ).reshape(-1)
        n = 1
        for d in g.shape:
            n *= d
        return summed[:n].reshape(g.shape), new_err

    out = jax.tree.map(one, grads, err)
    g_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_new, e_new


def error_feedback_init(params: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
