"""Training step factory: microbatched grad accumulation + AdamW.

The step is one jit'd function over sharded pytrees:

    (params fp32, opt_state, batch, step) -> (params, opt_state, metrics)

- **Microbatching**: the global batch is split into ``microbatches`` chunks
  scanned sequentially with fp32 gradient accumulation — this is what bounds
  the (batch·seq·vocab) logit buffer at 256k-vocab scale, and doubles as
  grad-accumulation elasticity: a smaller mesh raises ``microbatches``
  instead of failing.
- **Loss scaling**: fp16 policies scale the loss (policy.loss_scale) and
  unscale gradients; non-finite grads skip the update (adamw_update).
- **Remat** is configured per-arch on the block bodies (transformer.py).
- **Compressed DP**: ``make_shardmap_step`` runs the same math under
  shard_map with int8+error-feedback gradient all-reduce (optim.compress) —
  the collective-term optimization; numerics tested against the jit path.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import model as M
from repro.optim.adamw import OptConfig, adamw_update
from repro.optim.schedule import make_schedule

__all__ = ["TrainConfig", "make_train_step", "make_shardmap_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"
    opt: OptConfig = OptConfig()


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(model_cfg, policy, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step)."""
    sched = make_schedule(
        tcfg.schedule,
        peak_lr=tcfg.peak_lr,
        warmup_steps=tcfg.warmup_steps,
        total_steps=tcfg.total_steps,
    )

    def loss_for_grad(params, mb):
        # compute-dtype cast happens inside the model; params stay fp32
        loss, metrics = M.loss_fn(params, mb, model_cfg, policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(params, opt_state, batch, step):
        n = tcfg.microbatches
        micro = _split_micro(batch, n)

        def micro_body(acc, mb):
            g_acc, loss_acc = acc
            (loss, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (g_acc, loss_acc + loss), metrics

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (g_sum, loss_sum), metrics = jax.lax.scan(
            micro_body, (g0, jnp.zeros((), jnp.float32)), micro
        )
        inv = 1.0 / (n * policy.loss_scale)
        grads = jax.tree.map(lambda g: g * inv, g_sum)
        lr = sched(step)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, lr, tcfg.opt
        )
        out = {
            "loss": loss_sum / (n * policy.loss_scale),
            "lr": lr,
            **{k: v[-1] for k, v in metrics.items()},
            **opt_metrics,
        }
        return params, opt_state, out

    return train_step


def make_shardmap_step(model_cfg, policy, tcfg: TrainConfig, mesh, dp_axis="data",
                       compressed: bool = True):
    """Explicit-DP variant: per-shard grads + (int8) all-reduce under shard_map.

    params/opt replicated across ``dp_axis``; batch sharded on it.  Used to
    exercise/measure the compressed-gradient trick; the jit path above is
    the production default.
    """
    from jax.sharding import PartitionSpec as P

    from repro.optim import compress

    sched = make_schedule(
        tcfg.schedule, peak_lr=tcfg.peak_lr,
        warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps,
    )

    def local_step(params, opt_state, err, batch, step):
        # err leaves carry a leading per-shard axis of local size 1.
        err = jax.tree.map(lambda e: e[0], err)

        def loss_local(p, b):
            loss, metrics = M.loss_fn(p, b, model_cfg, policy)
            return loss, metrics

        (loss, _metrics), g = jax.value_and_grad(loss_local, has_aux=True)(
            params, batch
        )
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        if compressed:
            g, err = compress.compressed_psum(g, dp_axis, err)
        else:
            g = jax.lax.psum(g, dp_axis)
        nd = compat.axis_size(dp_axis)
        inv = 1.0 / (nd * policy.loss_scale)
        g = jax.tree.map(lambda x: x * inv, g)
        params, opt_state, om = adamw_update(
            params, g, opt_state, sched(step), tcfg.opt
        )
        loss = jax.lax.pmean(loss, dp_axis) / policy.loss_scale
        err = jax.tree.map(lambda e: e[None], err)
        return params, opt_state, err, {"loss": loss, **om}

    rep = P()
    bspec = P(dp_axis)
    return compat.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, bspec, bspec, rep),
        out_specs=(rep, rep, bspec, rep),
        check_vma=False,
    )
