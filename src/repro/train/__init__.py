"""Train/serve step factories."""

from repro.train.train_loop import TrainConfig, make_train_step  # noqa: F401
