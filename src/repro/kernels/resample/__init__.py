"""Systematic resampling: CDF build + search (paper kernel 6)."""

from repro.kernels.resample.ops import (  # noqa: F401
    inclusive_cumsum,
    systematic_resample,
)
