"""Pure-jnp oracle for the resampling kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["inclusive_cumsum_ref", "systematic_resample_ref"]


def inclusive_cumsum_ref(w: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    # analysis: allow(shared-body): the oracle must stay independent of the
    # kernel bodies it checks — XLA cumsum IS the reference
    return jnp.cumsum(w.astype(jnp.float32)).astype(out_dtype)


def systematic_resample_ref(
    u0: jax.Array, weights: jax.Array, num_out: int | None = None
) -> jax.Array:
    """searchsorted-based systematic resampling with fp32 CDF."""
    n_out = num_out or weights.shape[0]
    # analysis: allow(shared-body): oracle independence (see above)
    cdf = jnp.cumsum(weights.astype(jnp.float32))
    cdf = cdf / cdf[-1]
    # Multiply by the precomputed fp32 reciprocal — same arithmetic as the
    # kernel (which hoists 1/N a la the paper's XU fix), so results are
    # bitwise comparable even at CDF tie boundaries.
    u = (jnp.arange(n_out, dtype=jnp.float32) + u0.astype(jnp.float32)) * (
        jnp.float32(1.0 / n_out)
    )
    # analysis: allow(shared-body): oracle independence (see above)
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, weights.shape[0] - 1).astype(jnp.int32)
