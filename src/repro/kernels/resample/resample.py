"""Pallas kernels for systematic resampling (bank-batched).

Two kernels, matching the paper's resampling stage but reshaped for TPU:

1. ``cumsum``: inclusive prefix sum of the weight vector (the CDF the
   normalizing kernel prepares in the paper).  The TPU grid is sequential
   per core, so a blockwise cumsum with an fp32 SMEM carry is *exact* in a
   single pass — no hierarchical scan tree as on CUDA.  Within a block the
   row/lane decomposition keeps everything 2-D: lane-cumsum inside rows,
   row-total cumsum across rows, plus the running carry.

2. ``search``: invert the CDF at the systematic points u_g = (g + u0)/N.
   Each CUDA thread in the paper walks the CDF with a serial conditional
   chain; on TPU we do a vectorized binary search: the whole CDF stays
   resident in VMEM (64k particles = 256 KiB fp32, well under the ~16 MiB
   budget) and each step gathers one probe value per output lane.
   The searched constant ``1/N`` and offset u0 are precomputed scalars —
   the hoisting that fixed the paper's XU-pipeline bottleneck.

Both kernels carry a leading bank dimension: inputs are (B, rows, 128), one
bank row per independent filter of a :class:`~repro.core.engine.FilterBank`,
with the bank as the outermost (sequential) grid axis.  The cumsum carry is
re-initialized at block 0 of every bank row, so each filter's CDF is exact
and independent; the search takes a per-row u0 from SMEM.  ``B == 1``
reproduces the old single-filter kernels exactly.

The paper's key performance lesson (conversion-free inner loops) shows up
here as: probe indices are carried as int32 vectors, never round-tripped
through float, and the u-grid ramp is built once per block with
``broadcasted_iota`` in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["cumsum_call", "search_call", "LANES"]

LANES = 128


def _cumsum_kernel(x_ref, out_ref, carry_s):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        carry_s[0, 0] = jnp.float32(0.0)

    x = x_ref[0].astype(jnp.float32)  # (br, 128)
    lane_cum = jnp.cumsum(x, axis=1)  # within-row inclusive
    row_tot = lane_cum[:, -1:]  # (br, 1)
    row_prefix = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows
    block = lane_cum + row_prefix + carry_s[0, 0]
    out_ref[0] = block.astype(out_ref.dtype)
    carry_s[0, 0] = block[-1, -1]


def cumsum_call(
    x3d: jax.Array,
    *,
    block_rows: int,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    """Per-bank-row inclusive cumsum over row-major order of (B, rows, 128)."""
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0
    return pl.pallas_call(
        _cumsum_kernel,
        grid=(nbank, rows // block_rows),
        in_specs=[pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbank, rows, LANES), out_dtype),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(x3d)


def _search_kernel(u0_ref, cdf_ref, anc_ref, *, n_total: int, n_cdf: int):
    """Vectorized binary search of the systematic u-grid into one bank row.

    cdf_ref: this bank row's full (1, rows, 128) CDF in VMEM (normalized:
    last entry == 1).  u0_ref: this row's offset, (1, 1) in SMEM.
    anc_ref: (1, bo, 128) int32 output block of ancestor indices.
    Index of first cdf entry > u  ==  count of entries <= u (right-side
    searchsorted), computed by bisection on the flattened CDF.
    """
    o = pl.program_id(1)
    _, bo, lanes = anc_ref.shape
    base = o * (bo * lanes)
    # u-grid for this block, built in fp32 once (no per-step converts).
    ramp = jax.lax.broadcasted_iota(jnp.float32, (bo, lanes), 0) * lanes
    ramp = ramp + jax.lax.broadcasted_iota(jnp.float32, (bo, lanes), 1)
    u = (ramp + (jnp.float32(base) + u0_ref[0, 0])) * jnp.float32(
        1.0 / n_total
    )
    cdf = cdf_ref[0].reshape(-1)  # resident in VMEM/registers
    lo = jnp.zeros((bo, lanes), jnp.int32)  # lowest candidate
    hi = jnp.full((bo, lanes), n_cdf, jnp.int32)  # exclusive upper bound
    # answer lives in [lo, hi] — n_cdf+1 candidates — so bit_length(n_cdf)
    # bisection steps are required (bit_length(n_cdf-1) leaves {lo, lo+1}
    # unresolved and returns even-index answers only).
    steps = max(1, n_cdf.bit_length() if isinstance(n_cdf, int) else 16)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        val = jnp.take(cdf, mid, axis=0)
        gt = val <= u  # answer strictly right of mid
        return jnp.where(gt, mid + 1, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    anc_ref[0] = jnp.minimum(lo, n_cdf - 1)


def search_call(
    u0: jax.Array,
    cdf3d: jax.Array,
    *,
    n_total: int,
    num_out: int,
    block_rows_out: int,
    interpret: bool,
) -> jax.Array:
    """Ancestor indices (B, num_out) padded to (B, rows_out, 128) blocks.

    u0: (B,) per-bank-row systematic offsets; cdf3d: (B, rows, 128).
    """
    nbank, rows_cdf, lanes = cdf3d.shape
    assert lanes == LANES and u0.shape == (nbank,)
    rows_out = pl.cdiv(num_out, LANES)
    rows_out = ((rows_out + block_rows_out - 1) // block_rows_out) * block_rows_out
    n_cdf = rows_cdf * LANES
    kernel = functools.partial(
        _search_kernel, n_total=n_total, n_cdf=n_cdf
    )
    anc = pl.pallas_call(
        kernel,
        grid=(nbank, rows_out // block_rows_out),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, o: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rows_cdf, LANES), lambda b, o: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_rows_out, LANES), lambda b, o: (b, o, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((nbank, rows_out, LANES), jnp.int32),
        interpret=interpret,
    )(u0.reshape(nbank, 1).astype(jnp.float32), cdf3d)
    return anc.reshape(nbank, -1)[:, :num_out]
