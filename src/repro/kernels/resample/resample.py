"""Pallas kernels for systematic resampling (bank-batched).

Two kernels, matching the paper's resampling stage but reshaped for TPU:

1. ``cumsum``: inclusive prefix sum of the weight vector (the CDF the
   normalizing kernel prepares in the paper).  The TPU grid is sequential
   per core, so a blockwise cumsum with an fp32 SMEM carry is *exact* in a
   single pass — no hierarchical scan tree as on CUDA.  Within a block the
   row/lane decomposition keeps everything 2-D: lane-cumsum inside rows,
   row-total cumsum across rows, plus the running carry.

2. ``search``: invert the CDF at the systematic points u_g = (g + u0)/N.
   Each CUDA thread in the paper walks the CDF with a serial conditional
   chain; on TPU we do a vectorized binary search: the whole CDF stays
   resident in VMEM (64k particles = 256 KiB fp32, well under the ~16 MiB
   budget) and each step gathers one probe value per output lane.
   The searched constant ``1/N`` and offset u0 are precomputed scalars —
   the hoisting that fixed the paper's XU-pipeline bottleneck.

Both kernels carry a leading bank dimension: inputs are (B, rows, 128), one
bank row per independent filter of a :class:`~repro.core.engine.FilterBank`,
with the bank as the outermost (sequential) grid axis.  The cumsum carry is
re-initialized at block 0 of every bank row, so each filter's CDF is exact
and independent; the search takes a per-row u0 from SMEM.  ``B == 1``
reproduces the old single-filter kernels exactly.

The paper's key performance lesson (conversion-free inner loops) shows up
here as: probe indices are carried as int32 vectors, never round-tripped
through float, and the u-grid ramp is built once per block with
``broadcasted_iota`` in fp32.

Ragged banks: the ``masked`` variants take a per-row active count from SMEM.
The masked cumsum zeroes lanes at position >= n_active[b] before they enter
the carry; the masked search draws its systematic grid over the *active*
count — ``u_g = (g + u0) / n_active[b]`` — so only the first n_active
outputs are meaningful draws (later grid points fall past the CDF and clip
to the last entry; ragged callers pin those lanes to -inf weight).  Both the
dense and masked grids are built by IEEE fp32 division (``1.0f / n``), which
constant-folds bit-identically to its runtime value — the property the
ragged == dense equivalence tests rely on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    bisect_flat,
    cdf_block,
    flat_positions_f32,
    flat_positions_i32,
)

__all__ = [
    "cumsum_call",
    "masked_cumsum_call",
    "masked_search_call",
    "search_call",
    "LANES",
]

LANES = 128


def _cumsum_body(x, out_ref, carry_s):
    out_ref[0] = cdf_block(x, carry_s).astype(out_ref.dtype)


def _cumsum_kernel(x_ref, out_ref, carry_s):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        carry_s[0, 0] = jnp.float32(0.0)

    _cumsum_body(x_ref[0].astype(jnp.float32), out_ref, carry_s)


def _masked_cumsum_kernel(n_ref, x_ref, out_ref, carry_s):
    """As ``_cumsum_kernel`` with lanes >= this row's n_active zeroed before
    the carry — adding exact 0.0 terms, so the active prefix of the CDF is
    bitwise the unmasked kernel over that prefix alone."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        carry_s[0, 0] = jnp.float32(0.0)

    rows = x_ref.shape[1]
    pos = flat_positions_i32(i, rows, LANES)
    x = jnp.where(
        pos < n_ref[0, 0], x_ref[0].astype(jnp.float32), jnp.float32(0.0)
    )
    _cumsum_body(x, out_ref, carry_s)


def cumsum_call(
    x3d: jax.Array,
    *,
    block_rows: int,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    """Per-bank-row inclusive cumsum over row-major order of (B, rows, 128)."""
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0
    return pl.pallas_call(
        _cumsum_kernel,
        grid=(nbank, rows // block_rows),
        in_specs=[pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbank, rows, LANES), out_dtype),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(x3d)


def masked_cumsum_call(
    x3d: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    """Masked per-bank-row cumsum: n_active (B, 1) int32 per-row counts."""
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0
    assert n_active.shape == (nbank, 1), n_active.shape
    return pl.pallas_call(
        _masked_cumsum_kernel,
        grid=(nbank, rows // block_rows),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbank, rows, LANES), out_dtype),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(n_active.astype(jnp.int32), x3d)


def _bisect(u, cdf_ref, anc_ref, *, n_cdf: int):
    """Right-side searchsorted of the u-grid block into this row's CDF
    (the shared ``bisect_flat`` body; cdf resident in VMEM/registers)."""
    anc_ref[0] = bisect_flat(u, cdf_ref[0].reshape(-1), n_cdf=n_cdf)


def _search_kernel(u0_ref, cdf_ref, anc_ref, *, n_total: int, n_cdf: int):
    """Vectorized binary search of the systematic u-grid into one bank row.

    cdf_ref: this bank row's full (1, rows, 128) CDF in VMEM (normalized:
    last entry == 1).  u0_ref: this row's offset, (1, 1) in SMEM.
    anc_ref: (1, bo, 128) int32 output block of ancestor indices.
    Index of first cdf entry > u  ==  count of entries <= u (right-side
    searchsorted), computed by bisection on the flattened CDF.
    """
    o = pl.program_id(1)
    _, bo, lanes = anc_ref.shape
    pos = flat_positions_f32(o, bo, lanes)
    # IEEE fp32 reciprocal (folds bit-identically to the masked kernel's
    # runtime division — never the double-rounded Python 1.0 / n).
    inv = jnp.float32(1.0) / jnp.float32(n_total)
    u = (pos + u0_ref[0, 0]) * inv
    _bisect(u, cdf_ref, anc_ref, n_cdf=n_cdf)


def _masked_search_kernel(u0_ref, n_ref, cdf_ref, anc_ref, *, n_cdf: int):
    """As ``_search_kernel`` with this row's grid count read from SMEM:
    u_g = (g + u0) / n_active.  Grid points g >= n_active probe past the
    CDF and clip to the last entry — the ragged caller masks those lanes."""
    o = pl.program_id(1)
    _, bo, lanes = anc_ref.shape
    pos = flat_positions_f32(o, bo, lanes)
    n_f = jnp.maximum(n_ref[0, 0], 1).astype(jnp.float32)
    inv = jnp.float32(1.0) / n_f
    u = (pos + u0_ref[0, 0]) * inv
    _bisect(u, cdf_ref, anc_ref, n_cdf=n_cdf)


def search_call(
    u0: jax.Array,
    cdf3d: jax.Array,
    *,
    n_total: int,
    num_out: int,
    block_rows_out: int,
    interpret: bool,
) -> jax.Array:
    """Ancestor indices (B, num_out) padded to (B, rows_out, 128) blocks.

    u0: (B,) per-bank-row systematic offsets; cdf3d: (B, rows, 128).
    """
    nbank, rows_cdf, lanes = cdf3d.shape
    assert lanes == LANES and u0.shape == (nbank,)
    rows_out = pl.cdiv(num_out, LANES)
    rows_out = ((rows_out + block_rows_out - 1) // block_rows_out) * block_rows_out
    n_cdf = rows_cdf * LANES
    kernel = functools.partial(
        _search_kernel, n_total=n_total, n_cdf=n_cdf
    )
    anc = pl.pallas_call(
        kernel,
        grid=(nbank, rows_out // block_rows_out),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, o: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rows_cdf, LANES), lambda b, o: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_rows_out, LANES), lambda b, o: (b, o, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((nbank, rows_out, LANES), jnp.int32),
        interpret=interpret,
    )(u0.reshape(nbank, 1).astype(jnp.float32), cdf3d)
    return anc.reshape(nbank, -1)[:, :num_out]


def masked_search_call(
    u0: jax.Array,
    n_active: jax.Array,
    cdf3d: jax.Array,
    *,
    num_out: int,
    block_rows_out: int,
    interpret: bool,
) -> jax.Array:
    """Per-row-count systematic search: u_g = (g + u0[b]) / n_active[b].

    u0: (B,) offsets; n_active: (B,) int32 grid counts; cdf3d: (B, rows,
    128) normalized CDFs.  Returns (B, num_out) int32 ancestors — only the
    first n_active[b] of row ``b`` are meaningful systematic draws.
    """
    nbank, rows_cdf, lanes = cdf3d.shape
    assert lanes == LANES and u0.shape == (nbank,)
    assert n_active.shape == (nbank,), n_active.shape
    rows_out = pl.cdiv(num_out, LANES)
    rows_out = ((rows_out + block_rows_out - 1) // block_rows_out) * block_rows_out
    n_cdf = rows_cdf * LANES
    kernel = functools.partial(_masked_search_kernel, n_cdf=n_cdf)
    anc = pl.pallas_call(
        kernel,
        grid=(nbank, rows_out // block_rows_out),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, o: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, o: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rows_cdf, LANES), lambda b, o: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_rows_out, LANES), lambda b, o: (b, o, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((nbank, rows_out, LANES), jnp.int32),
        interpret=interpret,
    )(
        u0.reshape(nbank, 1).astype(jnp.float32),
        n_active.reshape(nbank, 1).astype(jnp.int32),
        cdf3d,
    )
    return anc.reshape(nbank, -1)[:, :num_out]
