"""Public entry points for the resampling kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.resample.resample import (
    LANES,
    cumsum_call,
    search_call,
)

__all__ = ["inclusive_cumsum", "systematic_resample"]

DEFAULT_BLOCK_ROWS = 64


@functools.partial(
    jax.jit, static_argnames=("block_rows", "out_dtype", "interpret")
)
def inclusive_cumsum(
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Exact single-pass inclusive cumsum of a 1-D vector (fp32 carry)."""
    if interpret is None:
        interpret = should_interpret()
    n = x.shape[0]
    x2d = pad_to_multiple(x, LANES * block_rows, axis=0, value=0).reshape(
        -1, LANES
    )
    out = cumsum_call(
        x2d, block_rows=block_rows, out_dtype=out_dtype, interpret=interpret
    )
    return out.reshape(-1)[:n]


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_resample(
    key: jax.Array,
    weights: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Systematic resampling of (possibly unnormalized) weights.

    CDF built by the carry-cumsum kernel (fp32), normalized by its final
    entry, then inverted at u_g = (g + u0)/N by the binary-search kernel.
    Padding weights are 0 ⇒ padded CDF entries repeat the total and are
    never selected by ``side='right'`` search (u < 1 strictly).
    """
    if interpret is None:
        interpret = should_interpret()
    n = weights.shape[0]
    n_out = num_out or n
    w2d = pad_to_multiple(
        weights, LANES * block_rows, axis=0, value=0
    ).reshape(-1, LANES)
    cdf2d = cumsum_call(
        w2d, block_rows=block_rows, out_dtype=jnp.float32, interpret=interpret
    )
    total = cdf2d[-1, -1]
    cdf2d = cdf2d / total
    u0 = jax.random.uniform(key, (), jnp.float32)
    anc = search_call(
        u0,
        cdf2d,
        n_total=n_out,
        num_out=n_out,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )
    return jnp.minimum(anc, n - 1)
