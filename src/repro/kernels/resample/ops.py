"""Public entry points for the resampling kernels.

1-D entry points handle one filter; the ``*_batched`` forms take a bank
(leading B axis, one independent filter per row) and run it as a single
kernel launch with per-row fp32 carries and per-row systematic offsets.

``systematic_ancestors_batched`` takes explicit per-row offsets instead of
keys: inside a meshed :class:`~repro.core.engine.FilterBank` the offsets
derive from the per-slot key chain *and* the device index (the RNA
``local`` scheme of ``repro.core.distributed``), so the caller owns the
u0 derivation and the kernel only inverts the CDF.

The ``*_masked`` forms add a per-row active count for ragged banks: lanes
at position >= n_active[b] are zeroed before the CDF carry and the
systematic grid spans the active count (u_g = (g + u0) / n_active[b]), so
the active prefix of a masked row is bitwise the unmasked kernel on a
width-n_active row; output lanes past the count clip to the CDF tail and
must be masked by the caller (the engine pins their weights to -inf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.resample.resample import (
    LANES,
    cumsum_call,
    masked_cumsum_call,
    masked_search_call,
    search_call,
)

__all__ = [
    "inclusive_cumsum",
    "systematic_ancestors_batched",
    "systematic_ancestors_masked",
    "systematic_resample",
    "systematic_resample_batched",
    "systematic_resample_masked",
]

DEFAULT_BLOCK_ROWS = 64


def _as_blocks(w: jax.Array, block_rows: int) -> jax.Array:
    x = pad_to_multiple(w, LANES * block_rows, axis=-1, value=0)
    return x.reshape(x.shape[:-1] + (-1, LANES))


@functools.partial(
    jax.jit, static_argnames=("block_rows", "out_dtype", "interpret")
)
def inclusive_cumsum(
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Exact single-pass inclusive cumsum of a 1-D vector (fp32 carry)."""
    if interpret is None:
        interpret = should_interpret()
    n = x.shape[0]
    x3d = _as_blocks(x, block_rows)[None]
    out = cumsum_call(
        x3d, block_rows=block_rows, out_dtype=out_dtype, interpret=interpret
    )
    return out.reshape(-1)[:n]


def _systematic_impl(u0, w2d, *, num_out, block_rows, block_rows_out, interpret):
    """(B,) offsets + (B, N) weights -> (B, num_out) ancestors."""
    nbank, n = w2d.shape
    w3d = _as_blocks(w2d, block_rows)
    cdf3d = cumsum_call(
        w3d, block_rows=block_rows, out_dtype=jnp.float32, interpret=interpret
    )
    total = cdf3d[:, -1, -1]
    cdf3d = cdf3d / total[:, None, None]
    anc = search_call(
        u0,
        cdf3d,
        n_total=num_out,
        num_out=num_out,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )
    return jnp.minimum(anc, n - 1)


def _systematic_masked_impl(
    u0, w2d, n_active, *, num_out, block_rows, block_rows_out, interpret
):
    """(B,) offsets + (B, N) weights + (B,) counts -> (B, num_out) ancestors."""
    nbank, n = w2d.shape
    w3d = _as_blocks(w2d, block_rows)
    cdf3d = masked_cumsum_call(
        w3d,
        n_active.reshape(nbank, 1),
        block_rows=block_rows,
        out_dtype=jnp.float32,
        interpret=interpret,
    )
    total = cdf3d[:, -1, -1]
    # Same unguarded division as the dense impl: a zero-mass row (n_active
    # = 0, or a shard slice holding none of its slot's mass) yields NaN
    # cdf and deterministic clipped garbage ancestors in *both* kernels —
    # those lanes carry -inf weight in the caller, and a full-count masked
    # launch stays bitwise the dense one.
    cdf3d = cdf3d / total[:, None, None]
    anc = masked_search_call(
        u0,
        n_active,
        cdf3d,
        num_out=num_out,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )
    return jnp.minimum(anc, n - 1)


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_resample(
    key: jax.Array,
    weights: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Systematic resampling of (possibly unnormalized) weights.

    CDF built by the carry-cumsum kernel (fp32), normalized by its final
    entry, then inverted at u_g = (g + u0)/N by the binary-search kernel.
    Padding weights are 0 ⇒ padded CDF entries repeat the total and are
    never selected by ``side='right'`` search (u < 1 strictly).
    """
    if interpret is None:
        interpret = should_interpret()
    n = weights.shape[0]
    u0 = jax.random.uniform(key, (), jnp.float32).reshape(1)
    anc = _systematic_impl(
        u0,
        weights[None],
        num_out=num_out or n,
        block_rows=block_rows,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )
    return anc[0]


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_resample_batched(
    keys: jax.Array,
    weights: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-row systematic resampling of a (B, P) weight bank.

    ``keys``: (B,) per-row PRNG keys — each filter draws its own offset, so
    rows resample independently (bit-identical to ``systematic_resample``
    row by row with the same keys).  Returns (B, num_out) int32 ancestors.
    """
    if interpret is None:
        interpret = should_interpret()
    nbank, n = weights.shape
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _systematic_impl(
        u0,
        weights,
        num_out=num_out or n,
        block_rows=block_rows,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_ancestors_batched(
    u0: jax.Array,
    weights: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-row systematic ancestors from explicit offsets.

    ``u0``: (B,) per-row offsets in [0, 1) — already drawn by the caller
    (the meshed bank folds the device index into each slot's key before
    drawing, so every shard inverts a distinct slice of its slot's
    systematic grid).  Semantics otherwise match
    ``systematic_resample_batched``.
    """
    if interpret is None:
        interpret = should_interpret()
    n = weights.shape[-1]
    return _systematic_impl(
        u0,
        weights,
        num_out=num_out or n,
        block_rows=block_rows,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_resample_masked(
    keys: jax.Array,
    weights: jax.Array,
    n_active: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Ragged per-row systematic resampling of a (B, P) weight bank.

    ``n_active``: (B,) int32 per-row active counts.  Lanes >= n_active[b]
    are zeroed before the CDF and the u-grid spans n_active[b] points, so
    ancestors at output positions < n_active[b] are bitwise
    ``systematic_resample`` on the width-n_active[b] prefix (same key);
    positions past the count clip to the CDF tail and must be masked by
    the caller.  ``n_active = P`` everywhere is bitwise
    ``systematic_resample_batched``.
    """
    if interpret is None:
        interpret = should_interpret()
    nbank, n = weights.shape
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _systematic_masked_impl(
        u0,
        weights,
        n_active,
        num_out=num_out or n,
        block_rows=block_rows,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "block_rows", "block_rows_out", "interpret"),
)
def systematic_ancestors_masked(
    u0: jax.Array,
    weights: jax.Array,
    n_active: jax.Array,
    *,
    num_out: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_rows_out: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Ragged per-row systematic ancestors from explicit offsets.

    The masked twin of ``systematic_ancestors_batched``: inside a meshed
    *ragged* bank each shard passes its per-slot shard-local active count
    (global count minus the shard's lane offset, clipped to the slice).
    """
    if interpret is None:
        interpret = should_interpret()
    n = weights.shape[-1]
    return _systematic_masked_impl(
        u0,
        weights,
        n_active,
        num_out=num_out or n,
        block_rows=block_rows,
        block_rows_out=block_rows_out,
        interpret=interpret,
    )
