"""Pallas kernel: the fused tracker step — likelihood → weights, one pass.

The fused epilogue (``repro.kernels.epilogue``) removed the HBM round trips
*inside* the weight pipeline, but a full tracker step is
likelihood-dominated: the composed chain still writes the per-particle
log-likelihoods to HBM, re-reads them to add the prior, and hands the
resulting (B, P) log-weight array to the epilogue, which reads it twice
more.  This kernel deletes all of that traffic.  Per bank row the grid is
one flat sequential segment chain (TPU grids run sequentially per core,
last dimension innermost):

segment L  (``nbp`` steps) likelihood: stream one (block_p, Jpad) patch
           chunk from HBM, score it with the shared ``loglik_rows`` body
           (paper Eq. 4), add the prior log-weight, mask positions past the
           row's particle count to -inf, and park the fp32 chunk in a VMEM
           log-weight scratch.  The (B, P) log-weight array never exists in
           HBM.
segments 0/1/2  (``3 * nw`` steps) the epilogue: the *same*
           ``_epilogue_body`` the fused epilogue kernel runs — online-LSE
           reduce, normalize + Kish sums + in-VMEM CDF, systematic search —
           reading its (block_rows, 128) fp32 blocks out of the scratch
           instead of HBM.

Bitwise contract: the per-row likelihood sum is independent of how rows are
grouped into chunks, the prior add is the same compute-dtype addition the
engine's ``log_weights + log_lik`` performs, and the scratch holds exactly
the fp32 values ``_as_blocks`` + ``astype(fp32)`` would have produced — so
every downstream phase folds identical blocks through identical op
sequences and the fused step reproduces the composed
``intensity_loglik → fused_epilogue`` chain bit for bit, dense, banked, and
masked (ragged ``n_active``), at fp32/bf16/fp16.

``fused_step_stats_call`` is the shard-local head for the meshed bank's
``local`` RNA scheme: the same likelihood segment, but the prior is a full
per-lane log-weight block (RNA weights are not uniform after a ring
exchange) and the tail is only the online-LSE stats reduce — the engine
merges ``(m, lse)`` across shards with the existing one-pmax+psum merge and
chains the existing ``fused_finalize`` kernels.  The shard's new cdt
log-weights are written once (they are carried state), but the fp32 stats
stream never touches HBM.

HBM traffic per row and step: read patches once, write weights and
ancestors once.  VMEM: the (rows, 128) fp32 log-weight scratch plus the
epilogue's (rows, 128) fp32 CDF scratch (512 KiB total at 64k particles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    flat_positions_i32,
    loglik_rows,
    online_lse_block,
    round_f32_to,
)
from repro.kernels.epilogue.epilogue import _epilogue_body

__all__ = [
    "fused_step_call",
    "fused_step_masked_call",
    "fused_step_stats_call",
    "fused_step_stats_masked_call",
    "LANES",
]

LANES = 128


def _loglik_chunk(x_ref, *, bg, fg, isq, accum16):
    """Score one (block_p, Jpad) patch chunk; return the (bpr, 128) fp32
    view of the log-likelihoods, rounded onto the compute-dtype grid
    (bpr = block_p / 128).  The explicit round stands in for the composed
    likelihood kernel's HBM write — the materialization point where that
    chain's values snap to the compute dtype."""
    x = x_ref[0]
    ll = loglik_rows(x, bg=bg, fg=fg, isq=isq, accum16=accum16)
    bpr = x.shape[0] // LANES
    ll32 = round_f32_to(ll.astype(jnp.float32), x.dtype)
    return ll32.reshape(bpr, LANES)


def _store_log_w(logw_s, t, lw32, n):
    """Park one fp32 log-weight chunk (already on the compute-dtype grid)
    in the scratch, pinning positions >= n to -inf — exactly the values
    ``_as_blocks`` padding plus the masked epilogue's position test would
    have produced."""
    bpr = lw32.shape[0]
    x32 = jnp.where(
        flat_positions_i32(t, bpr, LANES) < n,
        lw32,
        jnp.float32(-jnp.inf),
    )
    logw_s[pl.ds(t * bpr, bpr), :] = x32


def _step_kernel_body(
    t,
    n,
    inv,
    prior_ref,
    x_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    logw_s,
    u0_ref,
    *,
    bg,
    fg,
    isq,
    accum16,
    nbp,
    block_rows,
    n_cdf,
):
    """Shared dense/masked body: likelihood segment then epilogue phases."""

    @pl.when(t < nbp)
    def _loglik():
        ll32 = _loglik_chunk(x_ref, bg=bg, fg=fg, isq=isq, accum16=accum16)
        # fp32 add of on-grid values + round == the engine's compute-dtype
        # ``log_weights + log_lik`` (fp32 has >= 2p+2 significand bits for
        # both half formats, so there is no double-rounding error).
        lw32 = round_f32_to(prior_ref[0, 0] + ll32, x_ref.dtype)
        _store_log_w(logw_s, t, lw32, n)

    nw = logw_s.shape[0] // block_rows
    e = t - nbp
    # jnp floor division: e < 0 during the likelihood segment gives a
    # negative phase, so none of the epilogue's pl.when guards fire there.
    phase = e // nw
    i = e % nw
    x = logw_s[pl.ds(i * block_rows, block_rows), :]
    _epilogue_body(
        x, inv, phase, i, nw, u0_ref, w_ref, anc_ref, m_out, lse_out,
        sw_out, sw2_out, m_s, s_s, sw_s, sw2_s, carry_s, cdf_s, n_cdf=n_cdf,
    )


def _dense_kernel(
    u0_ref,
    prior_ref,
    x_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    logw_s,
    *,
    bg,
    fg,
    isq,
    accum16,
    n_total,
    nbp,
    block_rows,
    n_cdf,
):
    t = pl.program_id(1)
    inv = jnp.float32(1.0) / jnp.float32(n_total)
    _step_kernel_body(
        t, n_total, inv, prior_ref, x_ref, w_ref, anc_ref, m_out, lse_out,
        sw_out, sw2_out, m_s, s_s, sw_s, sw2_s, carry_s, cdf_s, logw_s,
        u0_ref, bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows, n_cdf=n_cdf,
    )


def _masked_kernel(
    u0_ref,
    prior_ref,
    n_ref,
    x_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    logw_s,
    *,
    bg,
    fg,
    isq,
    accum16,
    nbp,
    block_rows,
    n_cdf,
):
    """As ``_dense_kernel`` with this row's active count from SMEM: lanes at
    position >= n_active never reach the scratch finite and the u-grid
    spans the active count — the ragged-bank invariant."""
    t = pl.program_id(1)
    n = n_ref[0, 0]
    n_f = jnp.maximum(n, 1).astype(jnp.float32)
    inv = jnp.float32(1.0) / n_f
    _step_kernel_body(
        t, n, inv, prior_ref, x_ref, w_ref, anc_ref, m_out, lse_out,
        sw_out, sw2_out, m_s, s_s, sw_s, sw2_s, carry_s, cdf_s, logw_s,
        u0_ref, bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows, n_cdf=n_cdf,
    )


def _step_call(
    masked: bool,
    patches3d: jax.Array,
    u0: jax.Array,
    prior: jax.Array,
    n_active: jax.Array | None,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    n_total: int,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    nbank, p_pad, jpad = patches3d.shape
    assert jpad % LANES == 0, patches3d.shape
    assert p_pad % (block_rows * LANES) == 0, (p_pad, block_rows)
    assert block_p % LANES == 0 and (block_rows * LANES) % block_p == 0, (
        block_p,
        block_rows,
    )
    assert u0.shape == (nbank, 1) and prior.shape == (nbank, 1)
    rows = p_pad // LANES
    nbp = p_pad // block_p
    nw = rows // block_rows
    n_cdf = rows * LANES
    kernel = functools.partial(
        _masked_kernel if masked else _dense_kernel,
        bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows, n_cdf=n_cdf,
        **({} if masked else {"n_total": n_total}),
    )
    # Clamped index maps: each operand streams only during its own segment
    # and its current block is flushed right after the step that wrote it.
    patch_blk = pl.BlockSpec(
        (1, block_p, jpad), lambda b, t: (b, jnp.minimum(t, nbp - 1), 0)
    )
    w_blk = pl.BlockSpec(
        (1, block_rows, LANES),
        lambda b, t: (b, jnp.clip(t - nbp - nw, 0, nw - 1), 0),
    )
    anc_blk = pl.BlockSpec(
        (1, block_rows, LANES),
        lambda b, t: (b, jnp.clip(t - nbp - 2 * nw, 0, nw - 1), 0),
    )
    scalar = pl.BlockSpec((1, 1), lambda b, t: (b, 0))
    smem = pl.BlockSpec((1, 1), lambda b, t: (b, 0), memory_space=pltpu.SMEM)
    in_specs = [smem, smem] + ([smem] if masked else []) + [patch_blk]
    args = [u0.astype(jnp.float32), prior.astype(jnp.float32)]
    if masked:
        args.append(n_active.astype(jnp.int32))
    args.append(patches3d)
    return pl.pallas_call(
        kernel,
        grid=(nbank, nbp + 3 * nw),
        in_specs=in_specs,
        out_specs=[w_blk, anc_blk, scalar, scalar, scalar, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), patches3d.dtype),
            jax.ShapeDtypeStruct((nbank, rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def fused_step_call(
    patches3d: jax.Array,
    u0: jax.Array,
    prior: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    n_total: int,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    """patches3d: (B, P_pad, Jpad) compute-dtype patches (P padded to a
    multiple of ``block_rows * 128``); u0 / prior: (B, 1) fp32 systematic
    offsets and per-row uniform prior log-weights.

    Returns (w (B, rows, 128) in the patch dtype, ancestors (B, rows, 128)
    int32, m (B, 1), lse (B, 1), sum_w (B, 1), sum_w2 (B, 1)).
    """
    return _step_call(
        False, patches3d, u0, prior, None, bg=bg, fg=fg, isq=isq,
        accum16=accum16, n_total=n_total, block_p=block_p,
        block_rows=block_rows, interpret=interpret,
    )


def fused_step_masked_call(
    patches3d: jax.Array,
    u0: jax.Array,
    prior: jax.Array,
    n_active: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    """Masked form: adds (B, 1) int32 per-row active counts; ``prior`` is
    each row's ``log_uniform``.  Same output contract as the masked fused
    epilogue (inactive weight lanes 0, clipped ancestors)."""
    return _step_call(
        True, patches3d, u0, prior, n_active, bg=bg, fg=fg, isq=isq,
        accum16=accum16, n_total=0, block_p=block_p, block_rows=block_rows,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Shard-local head for the meshed bank's ``local`` RNA scheme: likelihood +
# prior add + online-LSE stats in one pass.  The prior here is a full
# per-lane log-weight block (RNA weights are not uniform after a ring
# exchange); the shard's new cdt log-weights are written once — they are
# carried state — and the engine merges the (m, lse) stats across shards
# before chaining the existing ``fused_finalize`` kernels.


def _head_body(
    t,
    n,
    prior_ref,
    x_ref,
    lw_ref,
    m_out,
    lse_out,
    m_s,
    s_s,
    logw_s,
    *,
    bg,
    fg,
    isq,
    accum16,
    nbp,
    block_rows,
):
    @pl.when(t < nbp)
    def _loglik():
        ll32 = _loglik_chunk(x_ref, bg=bg, fg=fg, isq=isq, accum16=accum16)
        bpr = ll32.shape[0]
        lw32 = round_f32_to(
            prior_ref[0].astype(jnp.float32) + ll32, x_ref.dtype
        )
        masked32 = jnp.where(
            flat_positions_i32(t, bpr, LANES) < n,
            lw32,
            jnp.float32(-jnp.inf),
        )
        logw_s[pl.ds(t * bpr, bpr), :] = masked32

    nw = logw_s.shape[0] // block_rows
    e = t - nbp

    @pl.when(e == 0)
    def _init():
        m_s[0, 0] = jnp.float32(-jnp.inf)
        s_s[0, 0] = jnp.float32(0.0)

    i = jnp.clip(e, 0, nw - 1)
    x = logw_s[pl.ds(i * block_rows, block_rows), :]

    @pl.when(e >= 0)
    def _reduce():
        # The cdt write-back of the carried log-weight state happens here,
        # from the scratch, not in the likelihood segment: the scratch holds
        # fp32 values already on the cdt grid (inactive lanes -inf — the
        # engine's ``where(active, log_w + log_lik, -inf)``) so the downcast
        # is exact, and keeping the likelihood block's only consumer the
        # scratch store keeps its lowering — LLVM contracts a multiply
        # feeding a subtract into an FMA per fusion context — identical to
        # the composed likelihood kernel's.
        lw_ref[0] = x.astype(lw_ref.dtype)
        online_lse_block(x, m_s, s_s)

    @pl.when(e == nw - 1)
    def _stats():
        m = m_s[0, 0]
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(s_s[0, 0]), m)
        m_out[0, 0] = m
        lse_out[0, 0] = lse


def _head_dense_kernel(
    prior_ref, x_ref, lw_ref, m_out, lse_out, m_s, s_s, logw_s,
    *, bg, fg, isq, accum16, n_total, nbp, block_rows,
):
    t = pl.program_id(1)
    _head_body(
        t, n_total, prior_ref, x_ref, lw_ref, m_out, lse_out, m_s, s_s,
        logw_s, bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows,
    )


def _head_masked_kernel(
    n_ref, prior_ref, x_ref, lw_ref, m_out, lse_out, m_s, s_s, logw_s,
    *, bg, fg, isq, accum16, nbp, block_rows,
):
    """Ragged twin: the per-row count is this shard's *local* active count."""
    t = pl.program_id(1)
    _head_body(
        t, n_ref[0, 0], prior_ref, x_ref, lw_ref, m_out, lse_out, m_s, s_s,
        logw_s, bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows,
    )


def _head_call(
    masked: bool,
    patches3d: jax.Array,
    prior3d: jax.Array,
    n_loc: jax.Array | None,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    n_total: int,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    nbank, p_pad, jpad = patches3d.shape
    assert jpad % LANES == 0, patches3d.shape
    assert p_pad % (block_rows * LANES) == 0, (p_pad, block_rows)
    assert block_p % LANES == 0 and (block_rows * LANES) % block_p == 0, (
        block_p,
        block_rows,
    )
    rows = p_pad // LANES
    bpr = block_p // LANES
    assert prior3d.shape == (nbank, rows, LANES), prior3d.shape
    nbp = p_pad // block_p
    nw = rows // block_rows
    kernel = functools.partial(
        _head_masked_kernel if masked else _head_dense_kernel,
        bg=bg, fg=fg, isq=isq, accum16=accum16, nbp=nbp,
        block_rows=block_rows,
        **({} if masked else {"n_total": n_total}),
    )
    patch_blk = pl.BlockSpec(
        (1, block_p, jpad), lambda b, t: (b, jnp.minimum(t, nbp - 1), 0)
    )
    prior_blk = pl.BlockSpec(
        (1, bpr, LANES), lambda b, t: (b, jnp.minimum(t, nbp - 1), 0)
    )
    # The cdt log-weight output streams out during the *stats* segment (one
    # block_rows-high block per step, read back from the fp32 scratch).
    lw_blk = pl.BlockSpec(
        (1, block_rows, LANES),
        lambda b, t: (b, jnp.clip(t - nbp, 0, nw - 1), 0),
    )
    scalar = pl.BlockSpec((1, 1), lambda b, t: (b, 0))
    smem = pl.BlockSpec((1, 1), lambda b, t: (b, 0), memory_space=pltpu.SMEM)
    in_specs = ([smem] if masked else []) + [prior_blk, patch_blk]
    args = ([n_loc.astype(jnp.int32)] if masked else []) + [
        prior3d,
        patches3d,
    ]
    return pl.pallas_call(
        kernel,
        grid=(nbank, nbp + nw),
        in_specs=in_specs,
        out_specs=[lw_blk, scalar, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), patches3d.dtype),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def fused_step_stats_call(
    patches3d: jax.Array,
    prior3d: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    n_total: int,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    """patches3d: (B, P_pad, Jpad); prior3d: (B, rows, 128) compute-dtype
    prior log-weights.  Returns (log_w (B, rows, 128) cdt, m (B, 1),
    lse (B, 1)) with fp32 shard-local online-LSE stats."""
    return _head_call(
        False, patches3d, prior3d, None, bg=bg, fg=fg, isq=isq,
        accum16=accum16, n_total=n_total, block_p=block_p,
        block_rows=block_rows, interpret=interpret,
    )


def fused_step_stats_masked_call(
    patches3d: jax.Array,
    prior3d: jax.Array,
    n_loc: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool,
    block_p: int,
    block_rows: int,
    interpret: bool,
):
    """Masked head: adds (B, 1) int32 shard-local active counts."""
    return _head_call(
        True, patches3d, prior3d, n_loc, bg=bg, fg=fg, isq=isq,
        accum16=accum16, n_total=0, block_p=block_p, block_rows=block_rows,
        interpret=interpret,
    )
