"""Fused step kernel: likelihood → weights in one streaming Pallas pass.

One kernel per bank row streams gathered observation patches through VMEM,
scores them with the stable intensity likelihood, and runs the full weight
pipeline (online-LSE → normalize → Kish sums → in-VMEM CDF → systematic
search) without ever materializing the (B, P) log-weight array in HBM.
"""

from repro.kernels.step.step import (
    LANES,
    fused_step_call,
    fused_step_masked_call,
    fused_step_stats_call,
    fused_step_stats_masked_call,
)

__all__ = [
    "LANES",
    "fused_step_call",
    "fused_step_masked_call",
    "fused_step_stats_call",
    "fused_step_stats_masked_call",
]
