"""Public entry points for the fused step kernel (likelihood → weights).

``fused_step`` handles one filter and the ``_batched`` / ``_masked`` forms
a (ragged) bank — one kernel launch per call that scores gathered patches
with the stable intensity likelihood, adds the prior log-weight, and runs
the full fused weight epilogue without materializing the (B, P) log-weight
array in HBM.  Systematic offsets are drawn from the caller's keys exactly
as the composed chain draws them, so with the same keys the fused step is
bitwise the composed ``intensity_loglik → fused_epilogue`` chain.

Return convention (the :class:`repro.core.engine.Backend` fused-step
contract): ``(weights, ancestors, log_z, max_log_w, sum_w, sum_w2)`` —
identical to the fused-epilogue contract, since the step kernel *is* that
epilogue with the likelihood fused in front.

``fused_step_stats_batched`` / ``_masked`` are the meshed shard-local
heads: likelihood + per-lane prior add + online-LSE stats in one pass,
returning ``(log_w, m, lse)`` for the engine's one-pmax+psum merge and the
existing ``fused_finalize`` tail.

Chunking knobs: ``block_p`` is the particle-chunk height of the streamed
likelihood segment (rows per VMEM-resident patch block); ``block_rows``
is the weight-pipeline block height.  ``block_rows`` must stay at the
epilogue's default for the bitwise contract — the online-LSE carry is
grouping-dependent — but ``block_p`` is a pure performance knob: the
per-row likelihood sum folds through the fixed ``pairwise_sum`` tree, so
any chunk height (a multiple of 128 dividing ``128 * block_rows``) gives
bit-identical results at every policy (see ``DEFAULT_BLOCK_P``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.step.step import (
    LANES,
    fused_step_call,
    fused_step_masked_call,
    fused_step_stats_call,
    fused_step_stats_masked_call,
)

__all__ = [
    "fused_step",
    "fused_step_batched",
    "fused_step_masked",
    "fused_step_stats_batched",
    "fused_step_stats_masked",
]

DEFAULT_BLOCK_ROWS = 64  # weight-pipeline blocks: must match the epilogue
# Particles per streamed likelihood chunk.  The per-row sum folds through
# the fixed ``pairwise_sum`` tree — an explicit op chain that is never
# reassociated — so it is bitwise independent of the chunk height at every
# policy, and ``block_p`` is a pure performance knob: any multiple of 128
# that divides 128*block_rows is bitwise the composed chain.  2048 streams
# a 1 MiB fp32 patch chunk per grid step (16x fewer likelihood-segment
# launches than the composed kernel's 128-row blocks) while staying far
# under VMEM even double-buffered.
DEFAULT_BLOCK_P = 2048


def _prep_patches(patches, model, policy, block_rows, block_p):
    """Cast to the compute dtype and pad: J to the 128-lane boundary and P
    to the weight-pipeline block multiple, both with the BG/FG midpoint
    (stable term exactly 0).  Pad rows are masked to -inf by position
    inside the kernel before they can reach any carry."""
    j = patches.shape[-1]
    isq = (model.scale * j) ** -0.5
    mid = 0.5 * (model.background + model.foreground)
    x = patches.astype(policy.compute_dtype)
    x = pad_to_multiple(x, LANES, axis=-1, value=mid)
    x = pad_to_multiple(x, LANES * block_rows, axis=-2, value=mid)
    accum16 = jnp.dtype(policy.accum_dtype).itemsize == 2
    return x, isq, accum16


def _step_impl(
    u0, patches, prior, n_active, *, model, policy, block_rows, block_p,
    interpret,
):
    nbank, n, _ = patches.shape
    x3d, isq, accum16 = _prep_patches(
        patches, model, policy, block_rows, block_p
    )
    common = dict(
        bg=model.background,
        fg=model.foreground,
        isq=isq,
        accum16=accum16,
        block_p=block_p,
        block_rows=block_rows,
        interpret=interpret,
    )
    if n_active is None:
        w3d, anc3d, m, lse, sw, sw2 = fused_step_call(
            x3d,
            u0.reshape(nbank, 1),
            prior.reshape(nbank, 1),
            n_total=n,
            **common,
        )
    else:
        w3d, anc3d, m, lse, sw, sw2 = fused_step_masked_call(
            x3d,
            u0.reshape(nbank, 1),
            prior.reshape(nbank, 1),
            n_active.reshape(nbank, 1),
            **common,
        )
    w = w3d.reshape(nbank, -1)[:, :n]
    anc = jnp.minimum(anc3d.reshape(nbank, -1)[:, :n], n - 1)
    return w, anc, lse[:, 0], m[:, 0], sw[:, 0], sw2[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("model", "policy", "block_rows", "block_p", "interpret"),
)
def fused_step(
    key: jax.Array,
    patches: jax.Array,
    model,
    prior: jax.Array,
    policy,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
):
    """One-pass (weights, ancestors, log_z, max, sum_w, sum_w2) for one
    filter from its (P, J) gathered patches and scalar uniform prior
    log-weight — bitwise the composed ``intensity_loglik`` →
    ``fused_epilogue`` chain with the same key."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.random.uniform(key, (), jnp.float32).reshape(1)
    w, anc, lse, m, sw, sw2 = _step_impl(
        u0,
        patches[None],
        prior.reshape(1),
        None,
        model=model,
        policy=policy,
        block_rows=block_rows,
        block_p=block_p,
        interpret=interpret,
    )
    return w[0], anc[0], lse[0], m[0], sw[0], sw2[0]


@functools.partial(
    jax.jit,
    static_argnames=("model", "policy", "block_rows", "block_p", "interpret"),
)
def fused_step_batched(
    keys: jax.Array,
    patches: jax.Array,
    model,
    prior: jax.Array,
    policy,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
):
    """Per-row fused step over a (B, P, J) patch bank: (B,) keys draw
    per-row offsets, ``prior`` is the (B,) per-row uniform prior
    log-weight; every row is bitwise ``fused_step`` on that row alone."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _step_impl(
        u0,
        patches,
        prior,
        None,
        model=model,
        policy=policy,
        block_rows=block_rows,
        block_p=block_p,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("model", "policy", "block_rows", "block_p", "interpret"),
)
def fused_step_masked(
    keys: jax.Array,
    patches: jax.Array,
    model,
    prior: jax.Array,
    policy,
    n_active: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
):
    """Ragged fused step: (B,) per-row active counts, ``prior`` the (B,)
    per-row ``log_uniform``.  The active prefix is bitwise the unmasked
    kernel on a width-n row whatever the inactive patch lanes hold;
    ancestors past the count clip and must be masked by the caller."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _step_impl(
        u0,
        patches,
        prior,
        n_active,
        model=model,
        policy=policy,
        block_rows=block_rows,
        block_p=block_p,
        interpret=interpret,
    )


def _stats_impl(
    patches, log_w, n_loc, *, model, policy, block_rows, block_p, interpret
):
    nbank, n = log_w.shape
    x3d, isq, accum16 = _prep_patches(
        patches, model, policy, block_rows, block_p
    )
    prior3d = pad_to_multiple(
        log_w.astype(policy.compute_dtype),
        LANES * block_rows,
        axis=-1,
        value=-jnp.inf,
    ).reshape(nbank, -1, LANES)
    common = dict(
        bg=model.background,
        fg=model.foreground,
        isq=isq,
        accum16=accum16,
        block_p=block_p,
        block_rows=block_rows,
        interpret=interpret,
    )
    if n_loc is None:
        lw3d, m, lse = fused_step_stats_call(
            x3d, prior3d, n_total=n, **common
        )
    else:
        lw3d, m, lse = fused_step_stats_masked_call(
            x3d, prior3d, n_loc.reshape(nbank, 1), **common
        )
    lw = lw3d.reshape(nbank, -1)[:, :n]
    return lw, m[:, 0], lse[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("model", "policy", "block_rows", "block_p", "interpret"),
)
def fused_step_stats_batched(
    patches: jax.Array,
    log_w: jax.Array,
    model,
    policy,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
):
    """Meshed shard-local head: (B, P_loc, J) patches + (B, P_loc) carried
    prior log-weights -> (new log_w (B, P_loc), m (B,), lse (B,)) — the
    likelihood, prior add, and shard-local online-LSE stats of the
    composed chain in one pass."""
    if interpret is None:
        interpret = should_interpret()
    return _stats_impl(
        patches,
        log_w,
        None,
        model=model,
        policy=policy,
        block_rows=block_rows,
        block_p=block_p,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("model", "policy", "block_rows", "block_p", "interpret"),
)
def fused_step_stats_masked(
    patches: jax.Array,
    log_w: jax.Array,
    model,
    policy,
    n_loc: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
):
    """Masked meshed head: (B,) *shard-local* active counts — lanes past
    the local count leave the kernel as -inf log-weights and contribute
    exactly 0 to the stats, the ragged-bank invariant."""
    if interpret is None:
        interpret = should_interpret()
    return _stats_impl(
        patches,
        log_w,
        n_loc,
        model=model,
        policy=policy,
        block_rows=block_rows,
        block_p=block_p,
        interpret=interpret,
    )
