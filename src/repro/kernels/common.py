"""Shared helpers for the Pallas kernels.

Besides the launch utilities, this module holds the *shared kernel
bodies* — the intensity-likelihood row sum, the online-logsumexp carry
fold, the blockwise-carry cumsum, the CDF bisection, and the flat
block-position builders.  The fused kernels (``repro.kernels.epilogue``,
``repro.kernels.step``) are bitwise-identical to the composed
likelihood→logsumexp→resample chain precisely because both sides execute
these same op sequences; keeping a single definition makes that
invariant structural instead of a copy-paste discipline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "bisect_flat",
    "cdf_block",
    "flat_positions_f32",
    "flat_positions_i32",
    "loglik_rows",
    "online_lse_block",
    "pairwise_sum",
    "round_f32_to",
    "should_interpret",
    "pad_to_multiple",
    "NEG_INF",
]

NEG_INF = float("-inf")


def pairwise_sum(t: jax.Array) -> jax.Array:
    """Sum over the last axis with a *fixed* pairwise tree of explicit
    adds.  ``jnp.sum`` lets XLA pick the reduction order per shape and
    fusion context — two programs summing identical rows can round
    differently at 16-bit (or by fp32 ulps) — whereas an explicit op
    chain is never reassociated, so this sum is bitwise independent of
    launch blocking and backend.  It is the canonical reduction order for
    the intensity likelihood: the jnp core path
    (``repro.core.likelihood``), the standalone likelihood kernel, and
    the fused step kernel all fold through it, which is what makes their
    outputs bitwise-comparable.  Trailing exact-zero padding lanes are
    free: a tail zero folds as ``x + 0`` at every level, so the padded
    tree equals the unpadded tree bit for bit."""
    while t.shape[-1] > 1:
        n = t.shape[-1]
        even = n - (n % 2)
        folded = t[..., 0:even:2] + t[..., 1:even:2]
        if n % 2:
            folded = jnp.concatenate([folded, t[..., -1:]], axis=-1)
        t = folded
    return t[..., 0]


def round_f32_to(x: jax.Array, dtype) -> jax.Array:
    """Round an fp32 array onto ``dtype``'s value grid (round-to-nearest-
    even), returning fp32.

    Why this exists: XLA's CPU backend computes 16-bit arithmetic in fp32
    and only rounds when a value materializes into a 16-bit buffer.  The
    composed likelihood→epilogue chain materializes its log-likelihoods
    and log-weights to HBM — two rounding points the fused step kernel
    deliberately removes.  Re-creating those rounds with integer bit ops
    (which the compiler cannot elide) keeps the fused kernel bitwise equal
    to the composed chain; on hardware whose 16-bit ops truly round, the
    incoming values are already on the grid and this is an exact no-op.
    fp32 and wider are returned unchanged.  Since fp32 carries at least
    2·p+2 significand bits for both half formats, the fp32-add-then-round
    composition equals a native 16-bit add (no double-rounding error).
    bf16 inputs below the fp32 normal floor (|x| < 2^-126) would need the
    coarser bf16-subnormal grid; log-weight magnitudes never reach it.
    """
    dt = jnp.dtype(dtype)
    if dt.itemsize >= 4:
        return x
    # analysis: allow(dtype-literal): round_f32_to *implements* the policy
    # grids — it branches on the target dtype, it does not choose one
    if dt == jnp.dtype(jnp.bfloat16):
        bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
        bias = jnp.uint32(0x7FFF) + ((bits >> 16) & jnp.uint32(1))
        rounded = jax.lax.bitcast_convert_type(
            (bits + bias) & jnp.uint32(0xFFFF0000), jnp.float32
        )
        return jnp.where(jnp.isnan(x), x, rounded)
    # analysis: allow(dtype-literal): same — the fp16-grid branch of the
    # shared rounding helper, dtype chosen by the caller's policy
    if dt == jnp.dtype(jnp.float16):
        ax = jnp.abs(x)
        # Subnormal range: the fp16 grid is uniform (2^-24); adding 0.5
        # aligns ax so fp32's own RNE lands on it exactly.
        half = jnp.float32(0.5)
        sub = (ax + half) - half
        # Normal range: Veltkamp split to 24-13 = 11 significand bits.
        c = ax * jnp.float32((1 << 13) + 1)
        norm = c - (c - ax)
        y = jnp.where(ax < jnp.float32(2.0**-14), sub, norm)
        # 65520 is the RNE overflow threshold (rounds to 2^16 -> inf).
        y = jnp.where(ax >= jnp.float32(65520.0), jnp.float32(jnp.inf), y)
        y = jnp.where(jnp.isnan(x), x, jnp.copysign(y, x))
        return y
    raise NotImplementedError(f"round_f32_to: unsupported dtype {dt}")


def loglik_rows(x, *, bg, fg, isq, accum16: bool) -> jax.Array:
    """Stable intensity log-likelihood of each row of a (rows, J) patch
    block (paper Eq. 4): sum_j [((I_j-BG)*isq)^2 - ((I_j-FG)*isq)^2],
    accumulated in the compute dtype when ``accum16`` else fp32.  Returns
    the (rows,) sums *in the accumulation dtype* — callers cast.  Shared
    by the standalone likelihood kernel and the fused step kernel so the
    likelihood→weights fusion is bitwise by construction: the pairwise
    tree keeps the per-row sum independent of the launch blocking."""
    cdt = x.dtype
    db = (x - jnp.asarray(bg, cdt)) * jnp.asarray(isq, cdt)
    df = (x - jnp.asarray(fg, cdt)) * jnp.asarray(isq, cdt)
    terms = db * db - df * df
    adt = cdt if accum16 else jnp.float32
    return pairwise_sum(terms.astype(adt))


def online_lse_block(x, m_s, s_s) -> None:
    """Fold one fp32 block into the online-logsumexp SMEM carry
    ``(m_s, s_s)``: new running max, rescale the running sum, add the
    block's exp-sum.  All-(-inf) streams keep ``s == 0`` under a neutral
    rescale so the caller's finalize can emit ``m`` itself.  Shared by the
    logsumexp, epilogue, and step kernels — the fused forms are bitwise
    the composed chain because every consumer folds identical (64, 128)
    fp32 blocks through this exact op sequence."""
    m_old = m_s[0, 0]
    m_new = jnp.maximum(m_old, jnp.max(x))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, jnp.float32(0.0))
    s_s[0, 0] = s_s[0, 0] * jnp.exp(m_old - m_safe) + jnp.sum(jnp.exp(x - m_safe))
    m_s[0, 0] = m_new


def flat_positions_i32(block_index, rows: int, lanes: int) -> jax.Array:
    """Flat int32 element positions of block ``block_index`` (mask tests)."""
    base = block_index * (rows * lanes)
    return (
        base
        + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0) * lanes
        + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    )


def flat_positions_f32(block_index, rows: int, lanes: int) -> jax.Array:
    """Flat fp32 element positions of block ``block_index`` — exact
    integers below 2^24, so ``pos + u0`` rounds identically whatever the
    launch blocking (the geometry-independence the fused epilogue's
    different output block shape relies on)."""
    base = block_index * (rows * lanes)
    ramp = jax.lax.broadcasted_iota(jnp.float32, (rows, lanes), 0) * lanes
    ramp = ramp + jax.lax.broadcasted_iota(jnp.float32, (rows, lanes), 1)
    return ramp + jnp.float32(base)


def cdf_block(x, carry_s):
    """One block of the blockwise-carry inclusive cumsum: lane-cumsum
    inside rows, exclusive row-total cumsum across rows, plus the running
    fp32 SMEM carry (updated in place).  Returns the fp32 block."""
    lane_cum = jnp.cumsum(x, axis=1)  # within-row inclusive
    row_tot = lane_cum[:, -1:]  # (br, 1)
    row_prefix = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows
    block = lane_cum + row_prefix + carry_s[0, 0]
    carry_s[0, 0] = block[-1, -1]
    return block


def bisect_flat(u, cdf, *, n_cdf: int) -> jax.Array:
    """Right-side searchsorted of ``u`` into the flat CDF vector by
    bisection: index of first cdf entry > u == count of entries <= u."""
    lo = jnp.zeros(u.shape, jnp.int32)  # lowest candidate
    hi = jnp.full(u.shape, n_cdf, jnp.int32)  # exclusive upper bound
    # answer lives in [lo, hi] — n_cdf+1 candidates — so bit_length(n_cdf)
    # bisection steps are required (bit_length(n_cdf-1) leaves {lo, lo+1}
    # unresolved and returns even-index answers only).
    steps = max(1, n_cdf.bit_length() if isinstance(n_cdf, int) else 16)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        val = jnp.take(cdf, mid, axis=0)
        gt = val <= u  # answer strictly right of mid
        return jnp.where(gt, mid + 1, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return jnp.minimum(lo, n_cdf - 1)


def should_interpret() -> bool:
    """Pallas interpret mode everywhere except on real TPU devices."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jax.Array, multiple: int, axis: int, value) -> jax.Array:
    """Pad ``axis`` of ``x`` up to a multiple with a constant value."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)
