"""Shared helpers for the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["should_interpret", "pad_to_multiple", "NEG_INF"]

NEG_INF = float("-inf")


def should_interpret() -> bool:
    """Pallas interpret mode everywhere except on real TPU devices."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jax.Array, multiple: int, axis: int, value) -> jax.Array:
    """Pad ``axis`` of ``x`` up to a multiple with a constant value."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)
