"""Shared helpers for the Pallas kernels.

Besides the launch utilities, this module holds the *shared kernel
bodies* — the blockwise-carry cumsum, the CDF bisection, and the flat
block-position builders.  The fused epilogue kernel
(``repro.kernels.epilogue``) is bitwise-identical to the composed
logsumexp→resample chain precisely because both execute these same op
sequences; keeping a single definition makes that invariant structural
instead of a copy-paste discipline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "bisect_flat",
    "cdf_block",
    "flat_positions_f32",
    "flat_positions_i32",
    "should_interpret",
    "pad_to_multiple",
    "NEG_INF",
]

NEG_INF = float("-inf")


def flat_positions_i32(block_index, rows: int, lanes: int) -> jax.Array:
    """Flat int32 element positions of block ``block_index`` (mask tests)."""
    base = block_index * (rows * lanes)
    return (
        base
        + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0) * lanes
        + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    )


def flat_positions_f32(block_index, rows: int, lanes: int) -> jax.Array:
    """Flat fp32 element positions of block ``block_index`` — exact
    integers below 2^24, so ``pos + u0`` rounds identically whatever the
    launch blocking (the geometry-independence the fused epilogue's
    different output block shape relies on)."""
    base = block_index * (rows * lanes)
    ramp = jax.lax.broadcasted_iota(jnp.float32, (rows, lanes), 0) * lanes
    ramp = ramp + jax.lax.broadcasted_iota(jnp.float32, (rows, lanes), 1)
    return ramp + jnp.float32(base)


def cdf_block(x, carry_s):
    """One block of the blockwise-carry inclusive cumsum: lane-cumsum
    inside rows, exclusive row-total cumsum across rows, plus the running
    fp32 SMEM carry (updated in place).  Returns the fp32 block."""
    lane_cum = jnp.cumsum(x, axis=1)  # within-row inclusive
    row_tot = lane_cum[:, -1:]  # (br, 1)
    row_prefix = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows
    block = lane_cum + row_prefix + carry_s[0, 0]
    carry_s[0, 0] = block[-1, -1]
    return block


def bisect_flat(u, cdf, *, n_cdf: int) -> jax.Array:
    """Right-side searchsorted of ``u`` into the flat CDF vector by
    bisection: index of first cdf entry > u == count of entries <= u."""
    lo = jnp.zeros(u.shape, jnp.int32)  # lowest candidate
    hi = jnp.full(u.shape, n_cdf, jnp.int32)  # exclusive upper bound
    # answer lives in [lo, hi] — n_cdf+1 candidates — so bit_length(n_cdf)
    # bisection steps are required (bit_length(n_cdf-1) leaves {lo, lo+1}
    # unresolved and returns even-index answers only).
    steps = max(1, n_cdf.bit_length() if isinstance(n_cdf, int) else 16)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        val = jnp.take(cdf, mid, axis=0)
        gt = val <= u  # answer strictly right of mid
        return jnp.where(gt, mid + 1, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return jnp.minimum(lo, n_cdf - 1)


def should_interpret() -> bool:
    """Pallas interpret mode everywhere except on real TPU devices."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jax.Array, multiple: int, axis: int, value) -> jax.Array:
    """Pad ``axis`` of ``x`` up to a multiple with a constant value."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)
