"""Pure-jnp oracle for the fused LSE normalization kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["online_logsumexp_ref", "normalize_weights_ref"]


def online_logsumexp_ref(log_w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(max, logsumexp) of a 1-D log-weight vector, fp32 accumulation."""
    x = log_w.astype(jnp.float32)
    m = jnp.max(x)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.float32(0.0))
    # analysis: allow(shared-body): the two-pass textbook LSE is the oracle
    # the online kernel is checked against — it must not share its body
    lse = m_safe + jnp.log(jnp.sum(jnp.exp(x - m_safe)))
    lse = jnp.where(jnp.isfinite(m), lse, m)
    return m, lse


def normalize_weights_ref(
    log_w: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(normalized weights, max, lse); weights in input dtype."""
    m, lse = online_logsumexp_ref(log_w)
    lse_safe = jnp.where(jnp.isfinite(lse), lse, jnp.float32(0.0))
    w = jnp.exp(log_w.astype(jnp.float32) - lse_safe).astype(log_w.dtype)
    return w, m, lse
