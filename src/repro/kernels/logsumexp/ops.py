"""Public entry points for the fused LSE kernel (jit'd, interpret-aware).

``normalize_weights`` handles one filter (1-D log-weights);
``normalize_weights_batched`` handles a bank (2-D, one row per filter) in a
single kernel launch with per-row fp32 carries — the kernel-level face of
:class:`repro.core.engine.FilterBank`.

``online_logsumexp`` / ``online_logsumexp_batched`` expose only the (max,
lse) reduction state: under mesh distribution they are the *shard-local*
pass of the paper's Eq.-5 — each device reduces its slice with the fused
kernel, and ``repro.core.distributed.dist_normalize[_banked]`` merges the
per-shard states with one ``pmax`` + one ``psum`` per row.

``normalize_weights_masked`` / ``online_logsumexp_masked`` are the ragged-
bank forms: a per-row active count pins lanes >= n_active[b] to -inf inside
the kernel carry, so a masked row with ``n_active = n`` is bitwise the
unmasked kernel on a width-``n`` row regardless of what the inactive lanes
hold, and ``n_active = P`` everywhere is bitwise the dense batched kernel.

The ``*_stats`` forms additionally return the Kish-ESS sums ``(sum_w,
sum_w2)`` of the rounded weight output, accumulated *inside* the normalize
phase — the engine derives ``ESS = sum_w^2 / sum_w2`` from them instead of
re-reading the whole weight array from HBM (one full (B, P) traversal
saved per step).  The plain forms run the same kernel and drop the sums.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.logsumexp.logsumexp import (
    LANES,
    fused_normalize_call,
    fused_normalize_masked_call,
)

__all__ = [
    "normalize_weights",
    "normalize_weights_batched",
    "normalize_weights_masked",
    "normalize_weights_stats",
    "normalize_weights_stats_batched",
    "normalize_weights_stats_masked",
    "online_logsumexp",
    "online_logsumexp_batched",
    "online_logsumexp_masked",
]

DEFAULT_BLOCK_ROWS = 64


def _as_blocks(log_w: jax.Array, block_rows: int) -> jax.Array:
    x = pad_to_multiple(log_w, LANES * block_rows, axis=-1, value=-jnp.inf)
    return x.reshape(x.shape[:-1] + (-1, LANES))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights_stats(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, ...]:
    """Fused (weights, max, lse, sum_w, sum_w2) over 1-D log-weights.

    Padding uses -inf (contributes exp(-inf)=0 to every sum and never wins
    the max); the padded tail of the weight output is sliced off.
    """
    if interpret is None:
        interpret = should_interpret()
    n = log_w.shape[0]
    x3d = _as_blocks(log_w, block_rows)[None]
    w3d, m, lse, sw, sw2 = fused_normalize_call(
        x3d, block_rows=block_rows, interpret=interpret
    )
    w = w3d.reshape(-1)[:n]
    return w, m[0, 0], lse[0, 0], sw[0, 0], sw2[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights_stats_batched(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, ...]:
    """Per-row fused (w (B, P), m (B,), lse (B,), sum_w (B,), sum_w2 (B,)).

    One kernel launch for the whole bank; each row reduces with its own
    fp32 carries, so the result is bit-identical to running
    ``normalize_weights_stats`` row by row.
    """
    if interpret is None:
        interpret = should_interpret()
    nbank, n = log_w.shape
    x3d = _as_blocks(log_w, block_rows)
    w3d, m, lse, sw, sw2 = fused_normalize_call(
        x3d, block_rows=block_rows, interpret=interpret
    )
    w = w3d.reshape(nbank, -1)[:, :n]
    return w, m[:, 0], lse[:, 0], sw[:, 0], sw2[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights_stats_masked(
    log_w: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, ...]:
    """Masked fused stats: (B, P) log-weights + (B,) counts.

    Inactive lanes carry weight exactly 0 through the Kish sums, so a
    masked row's (sum_w, sum_w2) are bitwise the unmasked kernel on the
    active prefix alone.
    """
    if interpret is None:
        interpret = should_interpret()
    nbank, n = log_w.shape
    x3d = _as_blocks(log_w, block_rows)
    w3d, m, lse, sw, sw2 = fused_normalize_masked_call(
        x3d,
        n_active.reshape(nbank, 1),
        block_rows=block_rows,
        interpret=interpret,
    )
    w = w3d.reshape(nbank, -1)[:, :n]
    return w, m[:, 0], lse[:, 0], sw[:, 0], sw2[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (normalized weights, max, lse) over a 1-D log-weight vector."""
    w, m, lse, _, _ = normalize_weights_stats(
        log_w, block_rows=block_rows, interpret=interpret
    )
    return w, m, lse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights_batched(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row fused normalize over a (B, P) bank of log-weight rows.

    One kernel launch for the whole bank: returns (w (B, P), m (B,),
    lse (B,)).  Each row reduces with its own fp32 carry, so the result is
    bit-identical to running ``normalize_weights`` row by row.
    """
    w, m, lse, _, _ = normalize_weights_stats_batched(
        log_w, block_rows=block_rows, interpret=interpret
    )
    return w, m, lse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def normalize_weights_masked(
    log_w: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row masked fused normalize: (B, P) log-weights + (B,) counts.

    Lanes at position >= n_active[b] never enter row ``b``'s carry (they are
    pinned to -inf inside the kernel) and come out with weight 0 — the
    ragged-bank contract: whatever junk an inactive lane holds, the active
    prefix is bitwise ``normalize_weights`` on that prefix alone.
    """
    w, m, lse, _, _ = normalize_weights_stats_masked(
        log_w, n_active, block_rows=block_rows, interpret=interpret
    )
    return w, m, lse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def online_logsumexp(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(max, lse) only — same kernel, weights output discarded by DCE-safe slice."""
    _, m, lse = normalize_weights(
        log_w, block_rows=block_rows, interpret=interpret
    )
    return m, lse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def online_logsumexp_batched(
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-row (max (B,), lse (B,)) of a (B, P) bank — the shard-local
    online-LSE state of the meshed :class:`~repro.core.engine.FilterBank`
    (``dist_normalize_banked`` merges these across the particle axes)."""
    _, m, lse = normalize_weights_batched(
        log_w, block_rows=block_rows, interpret=interpret
    )
    return m, lse


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def online_logsumexp_masked(
    log_w: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-row masked (max (B,), lse (B,)): the shard-local online-LSE state
    of a *ragged* meshed bank (lanes >= n_active[b] excluded in-kernel)."""
    _, m, lse = normalize_weights_masked(
        log_w, n_active, block_rows=block_rows, interpret=interpret
    )
    return m, lse
