"""Fused online log-sum-exp weight normalization (paper kernels 3-5)."""

from repro.kernels.logsumexp.ops import (  # noqa: F401
    normalize_weights,
    online_logsumexp,
)
