"""Pallas kernel: one-pass online LSE + fused normalization (bank-batched).

The paper runs three kernels per frame for weight handling: max-finding,
weighting (``exp(L - max L)``), and normalizing (divide by the sum).  The
log-sum-exp fix costs it "one more reduction" (paper section 4).  This
kernel removes that cost: a single streaming pass carries the running
``(max m, rescaled sum s)`` pair in SMEM — the same online rescaling used by
flash attention — then a second phase over the same blocks writes the
normalized weights.  Total traffic: read x twice, write w once; no separate
max pass.

Layout: each filter's 1-D weight vector is viewed as (rows, 128) so the last
dim fills the 128 VPU lanes; 16-bit inputs pack two elements per 32-bit
lane, which is the TPU equivalent of the paper's ``half2`` packing.
Accumulation is fp32 in SMEM (free on the VPU, unlike CUDA's FP16 pipe).

Bank axis: the kernel takes (B, rows, 128) — one row of blocks per
independent filter in a :class:`~repro.core.engine.FilterBank` — with grid
``(B, 2, num_blocks)``.  TPU grids run sequentially on a core with the last
dimension innermost, so for each bank row the reduce phase completes before
the normalize phase and the per-row fp32 SMEM carry is exact; the carry is
re-initialized at block 0 of every row.  ``B == 1`` is exactly the old
single-filter kernel.

VMEM per step: block_rows*128*itemsize (in) + block_rows*128*itemsize (out);
with the default block_rows=64 and bf16 that is 16 KiB + 16 KiB, independent
of B.

Ragged banks: the ``masked`` variant takes a per-row active count (SMEM
scalar per bank row) and pins every lane at position >= n_active to -inf in
the online carry — exp(-inf) = 0 never contributes to the sum and -inf
never wins the max, so a masked row with ``n_active = n`` is *bitwise* the
unmasked kernel on a width-``n`` row (extra all-masked blocks fold
``max(m, -inf)`` and ``s + 0.0``, both exact no-ops), whatever junk the
inactive lanes hold.  ``n_active = P`` on every row is bitwise the dense
kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import flat_positions_i32, online_lse_block

__all__ = ["fused_normalize_call", "fused_normalize_masked_call", "LANES"]

LANES = 128


def _body(x, phase, i, nb, w_ref, m_out, lse_out, sw_out, sw2_out, m_s, s_s,
          sw_s, sw2_s):
    """Shared reduce/normalize phases over one fp32 block ``x``."""

    @pl.when(phase == 0)
    def _reduce():
        online_lse_block(x, m_s, s_s)

    @pl.when(jnp.logical_and(phase == 0, i == nb - 1))
    def _stats():
        m = m_s[0, 0]
        lse = jnp.where(
            jnp.isfinite(m), m + jnp.log(s_s[0, 0]), m
        )
        m_out[0, 0] = m
        lse_out[0, 0] = lse
        s_s[0, 0] = lse  # reuse scratch: phase 1 reads this row's final lse

    @pl.when(phase == 1)
    def _normalize():
        @pl.when(i == 0)
        def _init_sums():
            sw_s[0, 0] = jnp.float32(0.0)
            sw2_s[0, 0] = jnp.float32(0.0)

        lse = s_s[0, 0]
        lse_safe = jnp.where(jnp.isfinite(lse), lse, jnp.float32(0.0))
        w = jnp.exp(x - lse_safe).astype(w_ref.dtype)
        w_ref[0] = w
        # Kish sums over the *rounded* weights (what the engine's ESS read
        # used to re-load from HBM): same pass, zero extra traffic.
        w32 = w.astype(jnp.float32)
        sw_s[0, 0] = sw_s[0, 0] + jnp.sum(w32)
        sw2_s[0, 0] = sw2_s[0, 0] + jnp.sum(w32 * w32)

    @pl.when(jnp.logical_and(phase == 1, i == nb - 1))
    def _sums():
        sw_out[0, 0] = sw_s[0, 0]
        sw2_out[0, 0] = sw2_s[0, 0]


def _kernel(x_ref, w_ref, m_out, lse_out, sw_out, sw2_out, m_s, s_s, sw_s,
            sw2_s):
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(jnp.logical_and(phase == 0, i == 0))
    def _init():
        m_s[0, 0] = jnp.float32(-jnp.inf)
        s_s[0, 0] = jnp.float32(0.0)

    x = x_ref[0].astype(jnp.float32)
    _body(x, phase, i, nb, w_ref, m_out, lse_out, sw_out, sw2_out, m_s, s_s,
          sw_s, sw2_s)


def _masked_kernel(n_ref, x_ref, w_ref, m_out, lse_out, sw_out, sw2_out, m_s,
                   s_s, sw_s, sw2_s):
    """As ``_kernel``, with lanes at position >= this row's n_active pinned
    to -inf before they enter the carry (and thus 0 in the weight output)."""
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(jnp.logical_and(phase == 0, i == 0))
    def _init():
        m_s[0, 0] = jnp.float32(-jnp.inf)
        s_s[0, 0] = jnp.float32(0.0)

    rows = x_ref.shape[1]
    x = jnp.where(
        flat_positions_i32(i, rows, LANES) < n_ref[0, 0],
        x_ref[0].astype(jnp.float32),
        jnp.float32(-jnp.inf),
    )
    _body(x, phase, i, nb, w_ref, m_out, lse_out, sw_out, sw2_out, m_s, s_s,
          sw_s, sw2_s)


def fused_normalize_call(
    x3d: jax.Array, *, block_rows: int, interpret: bool
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """x3d: (B, rows, 128) log-weights, one bank row per filter.

    Returns (w (B, rows, 128), m (B, 1), lse (B, 1), sum_w (B, 1),
    sum_w2 (B, 1)) with per-row stats — sum_w/sum_w2 are the Kish-ESS sums
    of the rounded weight output, accumulated in the normalize phase so the
    caller never re-reads the weights to compute ESS.
    """
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    nb = rows // block_rows
    w, m, lse, sw, sw2 = pl.pallas_call(
        _kernel,
        grid=(nbank, 2, nb),
        in_specs=[
            pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x3d)
    return w, m, lse, sw, sw2


def fused_normalize_masked_call(
    x3d: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Masked form: x3d (B, rows, 128), n_active (B, 1) int32 per-row counts.

    Lanes at flat position >= n_active[b] are treated as absent (-inf in the
    carry, 0 in the weight output — and exactly 0 in the Kish sums).
    Returns (w, m (B, 1), lse (B, 1), sum_w (B, 1), sum_w2 (B, 1)).
    """
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    assert n_active.shape == (nbank, 1), n_active.shape
    nb = rows // block_rows
    w, m, lse, sw, sw2 = pl.pallas_call(
        _masked_kernel,
        grid=(nbank, 2, nb),
        in_specs=[
            pl.BlockSpec(
                (1, 1), lambda b, p, i: (b, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, p, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(n_active.astype(jnp.int32), x3d)
    return w, m, lse, sw, sw2
