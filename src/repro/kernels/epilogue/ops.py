"""Public entry points for the fused weight-pipeline epilogue.

``fused_epilogue`` handles one filter (1-D log-weights) and the
``_batched`` / ``_masked`` forms a (ragged) bank — one kernel launch per
call, per-row fp32 carries, systematic offsets drawn from the caller's
keys exactly as the composed ``systematic_resample[_batched,_masked]``
chain draws them, so fused == composed holds bit for bit with the same
keys.

Return convention (the :class:`repro.core.engine.Backend` fused-epilogue
contract): ``(weights, ancestors, log_z, max_log_w, sum_w, sum_w2)`` —
``log_z`` is the row LSE, ``sum_w``/``sum_w2`` the Kish-ESS sums of the
rounded weights (``ESS = sum_w^2 / sum_w2``).

``fused_finalize_from_u0[_batched,_masked]`` are the shard-local meshed
forms: the *global* LSE (merged with one pmax + psum) comes in, and the
pass returns this shard's weights plus the RNA ``local`` scheme's
shard-local systematic ancestors (``ancestors_from_u0`` fused onto the
normalize pass) — ``(weights, ancestors)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.epilogue.epilogue import (
    LANES,
    fused_epilogue_call,
    fused_epilogue_masked_call,
    fused_finalize_call,
    fused_finalize_masked_call,
)

__all__ = [
    "fused_epilogue",
    "fused_epilogue_batched",
    "fused_epilogue_masked",
    "fused_finalize_from_u0_batched",
    "fused_finalize_from_u0_masked",
]

DEFAULT_BLOCK_ROWS = 64


def _as_blocks(log_w: jax.Array, block_rows: int) -> jax.Array:
    x = pad_to_multiple(log_w, LANES * block_rows, axis=-1, value=-jnp.inf)
    return x.reshape(x.shape[:-1] + (-1, LANES))


def _epilogue_impl(u0, log_w, n_active, *, block_rows, interpret):
    nbank, n = log_w.shape
    x3d = _as_blocks(log_w, block_rows)
    if n_active is None:
        w3d, anc3d, m, lse, sw, sw2 = fused_epilogue_call(
            x3d,
            u0.reshape(nbank, 1),
            n_total=n,
            block_rows=block_rows,
            interpret=interpret,
        )
    else:
        w3d, anc3d, m, lse, sw, sw2 = fused_epilogue_masked_call(
            x3d,
            u0.reshape(nbank, 1),
            n_active.reshape(nbank, 1),
            block_rows=block_rows,
            interpret=interpret,
        )
    w = w3d.reshape(nbank, -1)[:, :n]
    anc = jnp.minimum(anc3d.reshape(nbank, -1)[:, :n], n - 1)
    return w, anc, lse[:, 0], m[:, 0], sw[:, 0], sw2[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_epilogue(
    key: jax.Array,
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """One-pass (weights, ancestors, log_z, max, sum_w, sum_w2) for one
    filter — bitwise the composed ``normalize_weights`` → ESS →
    ``systematic_resample`` chain with the same key."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.random.uniform(key, (), jnp.float32).reshape(1)
    w, anc, lse, m, sw, sw2 = _epilogue_impl(
        u0, log_w[None], None, block_rows=block_rows, interpret=interpret
    )
    return w[0], anc[0], lse[0], m[0], sw[0], sw2[0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_epilogue_batched(
    keys: jax.Array,
    log_w: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Per-row fused epilogue over a (B, P) bank: (B,) keys draw per-row
    offsets; every row is bitwise ``fused_epilogue`` on that row alone."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _epilogue_impl(
        u0, log_w, None, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_epilogue_masked(
    keys: jax.Array,
    log_w: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Ragged fused epilogue: (B,) per-row active counts.  The active
    prefix is bitwise the unmasked kernel on a width-n row (junk-proof);
    ancestors past the count clip to the CDF tail and must be masked by
    the caller.  Full counts are bitwise ``fused_epilogue_batched``."""
    if interpret is None:
        interpret = should_interpret()
    u0 = jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(keys)
    return _epilogue_impl(
        u0, log_w, n_active, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_finalize_from_u0_batched(
    u0: jax.Array,
    log_w: jax.Array,
    lse: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Meshed shard-local epilogue tail: (B,) offsets + (B, P_loc) shard
    log-weights + (B,) merged global LSE -> (weights (B, P_loc),
    ancestors (B, P_loc)) — the weights of ``dist_normalize_banked``
    chained into ``systematic_ancestors_batched`` in one pass."""
    if interpret is None:
        interpret = should_interpret()
    nbank, n = log_w.shape
    x3d = _as_blocks(log_w, block_rows)
    w3d, anc3d = fused_finalize_call(
        x3d,
        lse.reshape(nbank, 1),
        u0.reshape(nbank, 1),
        n_total=n,
        block_rows=block_rows,
        interpret=interpret,
    )
    w = w3d.reshape(nbank, -1)[:, :n]
    anc = jnp.minimum(anc3d.reshape(nbank, -1)[:, :n], n - 1)
    return w, anc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_finalize_from_u0_masked(
    u0: jax.Array,
    log_w: jax.Array,
    lse: jax.Array,
    n_loc: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Masked meshed finalize: (B,) *shard-local* active counts — each
    shard resamples its active sub-slice; full counts are bitwise the
    dense form."""
    if interpret is None:
        interpret = should_interpret()
    nbank, n = log_w.shape
    x3d = _as_blocks(log_w, block_rows)
    w3d, anc3d = fused_finalize_masked_call(
        x3d,
        lse.reshape(nbank, 1),
        u0.reshape(nbank, 1),
        n_loc.reshape(nbank, 1),
        block_rows=block_rows,
        interpret=interpret,
    )
    w = w3d.reshape(nbank, -1)[:, :n]
    anc = jnp.minimum(anc3d.reshape(nbank, -1)[:, :n], n - 1)
    return w, anc
