"""Fused weight-pipeline epilogue: normalize + ESS + CDF + resample, one pass.

The composed chain traverses the (B, P) weight array ~5 times in HBM per
frame (normalize read+write, ESS read, cumsum read+write, search read); the
fused kernel here reads the log-weights twice (reduce + normalize phase),
writes the weights once and the ancestors once, and keeps the CDF entirely
in VMEM.  See ``repro.kernels.epilogue.epilogue`` for the kernel and
``ops`` for the jit'd entry points.
"""
