"""Pallas kernel: the fused weight-pipeline epilogue (bank-batched).

One kernel pass per bank row takes log-weights and emits everything the
engine's per-frame weight pipeline needs — normalized weights, ancestors,
``(max, lse)`` stats and the Kish-ESS sums — with the inclusive CDF living
only in VMEM scratch.  Phases per bank row (TPU grids are sequential per
core with the last dimension innermost, so phases complete in order):

phase 0  online-LSE reduce: running ``(max m, rescaled sum s)`` fp32 SMEM
         carry over the row's blocks (identical to ``kernels/logsumexp``).
phase 1  normalize + CDF: ``w = exp(x - lse)`` rounded to the compute
         dtype and written out; the *rounded* weights re-read as fp32 feed
         (a) the Kish sums ``sum_w`` / ``sum_w2`` (SMEM carries) and
         (b) the blockwise-carry inclusive cumsum whose blocks land in a
         VMEM scratch CDF — never HBM.  The final block divides the whole
         scratch CDF by its total (the same elementwise IEEE division the
         composed chain applies to its materialized CDF).
phase 2  systematic search: the u-grid ``u_g = (g + u0) * (1/N)`` is built
         from *flat* fp32 output positions (exact integers, so the grid is
         independent of the launch blocking) and binary-searched against
         the in-VMEM CDF — the same bisection as ``kernels/resample``.

Bitwise contract: with the same key, the fused kernel reproduces the
composed ``normalize → ESS → cumsum → search`` kernel chain exactly — same
per-block reduction order, same rounded weights into the CDF, same
division, same searches.  The ``masked`` variant adds a per-row active
count with the PR-4 invariant: the active prefix is bitwise the unmasked
kernel on a width-``n`` row whatever junk the inactive lanes hold, and
full counts are bitwise the dense kernel.

``fused_finalize_call`` is the shard-local variant for the meshed bank's
``local`` RNA scheme: the global LSE arrives from the one-``pmax``+``psum``
merge, and one pass computes the shard's weights and chains the
shard-local systematic inverse (``ancestors_from_u0``) on the in-VMEM CDF.

HBM traffic per row: read x twice, write w once, write ancestors once —
the (B, P) weight array is materialized exactly once per step.
VMEM: one (rows, 128) fp32 CDF scratch (256 KiB at 64k particles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    bisect_flat,
    cdf_block,
    flat_positions_f32,
    flat_positions_i32,
    online_lse_block,
)

__all__ = [
    "fused_epilogue_call",
    "fused_epilogue_masked_call",
    "fused_finalize_call",
    "fused_finalize_masked_call",
    "LANES",
]

LANES = 128


def _bisect_scratch(u, cdf_s, anc_ref, *, n_cdf: int):
    """Right-side searchsorted of ``u`` into the scratch CDF — the shared
    ``bisect_flat`` body the composed search kernel also runs."""
    anc_ref[0] = bisect_flat(u, cdf_s[:, :].reshape(-1), n_cdf=n_cdf)


def _epilogue_body(
    x,
    inv,
    phase,
    i,
    nb,
    u0_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    *,
    n_cdf: int,
):
    """Shared reduce / normalize+CDF / search phases over one fp32 block.

    ``x`` is the (masked) fp32 log-weight block; ``inv`` the row's fp32
    reciprocal grid spacing (1/N dense, 1/n_active masked).
    """

    @pl.when(jnp.logical_and(phase == 0, i == 0))
    def _init():
        m_s[0, 0] = jnp.float32(-jnp.inf)
        s_s[0, 0] = jnp.float32(0.0)

    @pl.when(phase == 0)
    def _reduce():
        online_lse_block(x, m_s, s_s)

    @pl.when(jnp.logical_and(phase == 0, i == nb - 1))
    def _stats():
        m = m_s[0, 0]
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(s_s[0, 0]), m)
        m_out[0, 0] = m
        lse_out[0, 0] = lse
        s_s[0, 0] = lse  # reuse scratch: phase 1 reads this row's final lse

    @pl.when(phase == 1)
    def _normalize_cdf():
        @pl.when(i == 0)
        def _init1():
            sw_s[0, 0] = jnp.float32(0.0)
            sw2_s[0, 0] = jnp.float32(0.0)
            carry_s[0, 0] = jnp.float32(0.0)

        lse = s_s[0, 0]
        lse_safe = jnp.where(jnp.isfinite(lse), lse, jnp.float32(0.0))
        w = jnp.exp(x - lse_safe).astype(w_ref.dtype)
        w_ref[0] = w
        # The *rounded* weights feed the Kish sums and the CDF — exactly
        # what the composed chain re-reads from HBM.
        w32 = w.astype(jnp.float32)
        sw_s[0, 0] = sw_s[0, 0] + jnp.sum(w32)
        sw2_s[0, 0] = sw2_s[0, 0] + jnp.sum(w32 * w32)
        rows = w_ref.shape[1]
        cdf_s[pl.ds(i * rows, rows), :] = cdf_block(w32, carry_s)

    @pl.when(jnp.logical_and(phase == 1, i == nb - 1))
    def _fin1():
        sw_out[0, 0] = sw_s[0, 0]
        sw2_out[0, 0] = sw2_s[0, 0]
        # Normalize the whole in-VMEM CDF by its total: the same unguarded
        # elementwise IEEE division the composed chain applies (zero-mass
        # rows go NaN and clip deterministically in both).
        cdf_s[:, :] = cdf_s[:, :] / carry_s[0, 0]

    @pl.when(phase == 2)
    def _search():
        rows = anc_ref.shape[1]
        pos = flat_positions_f32(i, rows, LANES)
        u = (pos + u0_ref[0, 0]) * inv
        _bisect_scratch(u, cdf_s, anc_ref, n_cdf=n_cdf)


def _dense_kernel(
    u0_ref,
    x_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    *,
    n_total: int,
    n_cdf: int,
):
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    x = x_ref[0].astype(jnp.float32)
    # IEEE fp32 reciprocal — folds bit-identically to the masked kernel's
    # runtime division (the PR-4 invariant).
    inv = jnp.float32(1.0) / jnp.float32(n_total)
    _epilogue_body(
        x, inv, phase, i, nb, u0_ref, w_ref, anc_ref, m_out, lse_out,
        sw_out, sw2_out, m_s, s_s, sw_s, sw2_s, carry_s, cdf_s, n_cdf=n_cdf,
    )


def _masked_kernel(
    u0_ref,
    n_ref,
    x_ref,
    w_ref,
    anc_ref,
    m_out,
    lse_out,
    sw_out,
    sw2_out,
    m_s,
    s_s,
    sw_s,
    sw2_s,
    carry_s,
    cdf_s,
    *,
    n_cdf: int,
):
    """As ``_dense_kernel`` with this row's active count from SMEM: lanes at
    position >= n_active are pinned to -inf before any carry (weight and
    Kish contribution exactly 0) and the u-grid spans the active count."""
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    rows = x_ref.shape[1]
    x = jnp.where(
        flat_positions_i32(i, rows, LANES) < n_ref[0, 0],
        x_ref[0].astype(jnp.float32),
        jnp.float32(-jnp.inf),
    )
    n_f = jnp.maximum(n_ref[0, 0], 1).astype(jnp.float32)
    inv = jnp.float32(1.0) / n_f
    _epilogue_body(
        x, inv, phase, i, nb, u0_ref, w_ref, anc_ref, m_out, lse_out,
        sw_out, sw2_out, m_s, s_s, sw_s, sw2_s, carry_s, cdf_s, n_cdf=n_cdf,
    )


def fused_epilogue_call(
    x3d: jax.Array,
    u0: jax.Array,
    *,
    n_total: int,
    block_rows: int,
    interpret: bool,
):
    """x3d: (B, rows, 128) log-weights; u0: (B, 1) fp32 systematic offsets.

    Returns (w (B, rows, 128) in x3d's dtype, ancestors (B, rows, 128)
    int32, m (B, 1), lse (B, 1), sum_w (B, 1), sum_w2 (B, 1)).
    """
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    assert u0.shape == (nbank, 1), u0.shape
    nb = rows // block_rows
    n_cdf = rows * LANES
    kernel = functools.partial(_dense_kernel, n_total=n_total, n_cdf=n_cdf)
    blk = pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0))
    scalar = pl.BlockSpec((1, 1), lambda b, p, i: (b, 0))
    return pl.pallas_call(
        kernel,
        grid=(nbank, 3, nb),
        in_specs=[
            pl.BlockSpec(
                (1, 1), lambda b, p, i: (b, 0), memory_space=pltpu.SMEM
            ),
            blk,
        ],
        out_specs=[blk, blk, scalar, scalar, scalar, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(u0.astype(jnp.float32), x3d)


def fused_epilogue_masked_call(
    x3d: jax.Array,
    u0: jax.Array,
    n_active: jax.Array,
    *,
    block_rows: int,
    interpret: bool,
):
    """Masked form: adds (B, 1) int32 per-row active counts.

    Output lanes at position >= n_active[b] hold weight 0 and clipped
    ancestor draws the caller must mask (the engine pins their weights to
    -inf) — same contract as the composed masked kernel chain.
    """
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    assert u0.shape == (nbank, 1), u0.shape
    assert n_active.shape == (nbank, 1), n_active.shape
    nb = rows // block_rows
    n_cdf = rows * LANES
    kernel = functools.partial(_masked_kernel, n_cdf=n_cdf)
    blk = pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0))
    scalar = pl.BlockSpec((1, 1), lambda b, p, i: (b, 0))
    smem = pl.BlockSpec(
        (1, 1), lambda b, p, i: (b, 0), memory_space=pltpu.SMEM
    )
    return pl.pallas_call(
        kernel,
        grid=(nbank, 3, nb),
        in_specs=[smem, smem, blk],
        out_specs=[blk, blk, scalar, scalar, scalar, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(u0.astype(jnp.float32), n_active.astype(jnp.int32), x3d)


# ---------------------------------------------------------------------------
# Shard-local finalize: the meshed bank's fused epilogue tail.  The global
# LSE is already merged (one pmax + psum across the particle axes); one
# pass computes this shard's weights and chains the shard-local systematic
# inverse on the in-VMEM CDF (the RNA ``local`` scheme's ancestors_from_u0).


def _finalize_body(
    x, inv, phase, i, nb, u0_ref, lse_ref, w_ref, anc_ref, carry_s, cdf_s,
    *, n_cdf: int,
):
    @pl.when(phase == 0)
    def _normalize_cdf():
        @pl.when(i == 0)
        def _init():
            carry_s[0, 0] = jnp.float32(0.0)

        lse = lse_ref[0, 0]
        lse_safe = jnp.where(jnp.isfinite(lse), lse, jnp.float32(0.0))
        w = jnp.exp(x - lse_safe).astype(w_ref.dtype)
        w_ref[0] = w
        w32 = w.astype(jnp.float32)
        rows = w_ref.shape[1]
        cdf_s[pl.ds(i * rows, rows), :] = cdf_block(w32, carry_s)

    @pl.when(jnp.logical_and(phase == 0, i == nb - 1))
    def _fin0():
        cdf_s[:, :] = cdf_s[:, :] / carry_s[0, 0]

    @pl.when(phase == 1)
    def _search():
        rows = anc_ref.shape[1]
        pos = flat_positions_f32(i, rows, LANES)
        u = (pos + u0_ref[0, 0]) * inv
        _bisect_scratch(u, cdf_s, anc_ref, n_cdf=n_cdf)


def _finalize_kernel(
    u0_ref, lse_ref, x_ref, w_ref, anc_ref, carry_s, cdf_s,
    *, n_total: int, n_cdf: int,
):
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    x = x_ref[0].astype(jnp.float32)
    inv = jnp.float32(1.0) / jnp.float32(n_total)
    _finalize_body(
        x, inv, phase, i, nb, u0_ref, lse_ref, w_ref, anc_ref, carry_s,
        cdf_s, n_cdf=n_cdf,
    )


def _masked_finalize_kernel(
    u0_ref, lse_ref, n_ref, x_ref, w_ref, anc_ref, carry_s, cdf_s,
    *, n_cdf: int,
):
    """Ragged twin: the per-row count is this shard's *local* active count
    (``clip(n_active - d*P_loc, 0, P_loc)``) — lanes past it are pinned to
    -inf (weight exactly 0) and the u-grid spans the local count."""
    phase = pl.program_id(1)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    rows = x_ref.shape[1]
    x = jnp.where(
        flat_positions_i32(i, rows, LANES) < n_ref[0, 0],
        x_ref[0].astype(jnp.float32),
        jnp.float32(-jnp.inf),
    )
    n_f = jnp.maximum(n_ref[0, 0], 1).astype(jnp.float32)
    inv = jnp.float32(1.0) / n_f
    _finalize_body(
        x, inv, phase, i, nb, u0_ref, lse_ref, w_ref, anc_ref, carry_s,
        cdf_s, n_cdf=n_cdf,
    )


def fused_finalize_call(
    x3d: jax.Array,
    lse: jax.Array,
    u0: jax.Array,
    *,
    n_total: int,
    block_rows: int,
    interpret: bool,
):
    """x3d: (B, rows, 128) log-weights; lse: (B, 1) fp32 *global* LSE;
    u0: (B, 1) fp32 offsets.  Returns (w, ancestors) blocks."""
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    assert lse.shape == (nbank, 1) and u0.shape == (nbank, 1)
    nb = rows // block_rows
    n_cdf = rows * LANES
    kernel = functools.partial(_finalize_kernel, n_total=n_total, n_cdf=n_cdf)
    blk = pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0))
    smem = pl.BlockSpec(
        (1, 1), lambda b, p, i: (b, 0), memory_space=pltpu.SMEM
    )
    return pl.pallas_call(
        kernel,
        grid=(nbank, 2, nb),
        in_specs=[smem, smem, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, rows, LANES), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(u0.astype(jnp.float32), lse.astype(jnp.float32), x3d)


def fused_finalize_masked_call(
    x3d: jax.Array,
    lse: jax.Array,
    u0: jax.Array,
    n_loc: jax.Array,
    *,
    block_rows: int,
    interpret: bool,
):
    """Masked finalize: adds (B, 1) int32 *shard-local* active counts."""
    nbank, rows, lanes = x3d.shape
    assert lanes == LANES and rows % block_rows == 0, (x3d.shape, block_rows)
    assert lse.shape == (nbank, 1) and u0.shape == (nbank, 1)
    assert n_loc.shape == (nbank, 1), n_loc.shape
    nb = rows // block_rows
    n_cdf = rows * LANES
    kernel = functools.partial(_masked_finalize_kernel, n_cdf=n_cdf)
    blk = pl.BlockSpec((1, block_rows, LANES), lambda b, p, i: (b, i, 0))
    smem = pl.BlockSpec(
        (1, 1), lambda b, p, i: (b, 0), memory_space=pltpu.SMEM
    )
    return pl.pallas_call(
        kernel,
        grid=(nbank, 2, nb),
        in_specs=[smem, smem, smem, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, rows, LANES), x3d.dtype),
            jax.ShapeDtypeStruct((nbank, rows, LANES), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(
        u0.astype(jnp.float32),
        lse.astype(jnp.float32),
        n_loc.astype(jnp.int32),
        x3d,
    )
