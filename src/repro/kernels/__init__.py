"""Pallas TPU kernels for the paper's compute hot-spots.

Three kernels, mirroring the paper's optimized CUDA kernels (section 4):

- ``logsumexp``   — fused max-finding + weighting + normalizing (the paper's
  kernels 3-5) as a single-pass online LSE with SMEM carry; the ``_stats``
  forms also accumulate the Kish-ESS sums in the normalize phase.
- ``resample``    — CDF build (blockwise-carry inclusive cumsum) + systematic
  resampling search (vectorized binary search), the paper's kernel 6.
- ``epilogue``    — the whole weight pipeline (kernels 3-6) in ONE pass:
  normalize + ESS + CDF + systematic search, the CDF living only in VMEM;
  bitwise-identical to the composed logsumexp→resample chain.  Includes the
  shard-local ``finalize`` variant the meshed bank's RNA scheme chains onto
  the one-pmax+psum LSE merge.
- ``likelihood``  — stable scaled-square intensity likelihood with fused
  running max (the paper's kernels 2-3).

Each kernel package ships ``ops.py`` (jit'd public entry points, interpret
mode auto-selected off-TPU) and ``ref.py`` (pure-jnp oracle used by tests).

TPU adaptation notes (vs. the paper's CUDA ``half2`` scheme): VPU lanes are
32-bit, so 16-bit arrays pack two elements per lane — the *layout* provides
the paper's 2-per-instruction packing; accumulator scratch is fp32, which is
free on the VPU (unlike CUDA's FP16 pipe) and removes the paper's CDF
round-off; block shapes keep the last dim a multiple of 128 lanes.
"""
