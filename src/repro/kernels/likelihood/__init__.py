"""Stable intensity likelihood with fused running max (paper kernels 2-3)."""

from repro.kernels.likelihood.ops import intensity_loglik  # noqa: F401
