"""Public entry point for the fused likelihood kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to_multiple, should_interpret
from repro.kernels.likelihood.likelihood import LANES, loglik_call

__all__ = ["intensity_loglik", "intensity_loglik_with_max"]

DEFAULT_BLOCK_P = 128


@functools.partial(
    jax.jit, static_argnames=("model", "policy", "block_p", "interpret")
)
def intensity_loglik_with_max(
    patches: jax.Array,
    model,
    policy,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """((P,) log-likelihoods, running max fp32) from gathered patches.

    ``model``: IntensityModel (hashable dataclass — static under jit);
    ``policy``: PrecisionPolicy.

    Padding: the J axis pads with the BG/FG midpoint, whose per-point term
    is exactly 0, so padded lanes never perturb a row's log-likelihood.
    The P axis also pads with midpoint rows — each scores exactly 0, which
    is NOT a safe max sentinel (real rows are typically all negative).  The
    kernel's fused running max is therefore only trusted when the P axis
    needed no padding; whenever ``p_pad > 0`` it is discarded and the max
    is recomputed as ``jnp.max`` in fp32 over the sliced real rows.  Either
    way the returned max is over real rows only; the log-likelihood output
    is always sliced to the first ``p`` rows.
    """
    if interpret is None:
        interpret = should_interpret()
    p, j = patches.shape
    n = j  # true number of points for the scale constant
    isq = (model.scale * n) ** -0.5
    mid = 0.5 * (model.background + model.foreground)
    x = patches.astype(policy.compute_dtype)
    x = pad_to_multiple(x, LANES, axis=1, value=mid)
    p_pad = (-p) % block_p
    x = pad_to_multiple(x, block_p, axis=0, value=mid)
    accum16 = jnp.dtype(policy.accum_dtype).itemsize == 2
    ll2d, m = loglik_call(
        x,
        bg=model.background,
        fg=model.foreground,
        isq=isq,
        block_p=block_p,
        accum16=accum16,
        interpret=interpret,
    )
    ll = ll2d[:p, 0]
    m = m[0, 0]
    if p_pad:
        # Padded rows scored exactly 0; recover the true max over real rows.
        m = jnp.max(ll.astype(jnp.float32))
    return ll, m


def intensity_loglik(
    patches: jax.Array, model, policy, **kw
) -> jax.Array:
    ll, _ = intensity_loglik_with_max(patches, model, policy, **kw)
    return ll
