"""Pure-jnp oracle for the likelihood kernel (= core.likelihood stable path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import pairwise_sum

__all__ = ["intensity_loglik_ref"]


def intensity_loglik_ref(
    patches: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    accum16: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Stable Eq.-4 log-likelihood + max over particles.

    patches: (P, J) in the compute dtype. Returns ((P,) loglik, max fp32).
    """
    cdt = patches.dtype
    db = (patches - jnp.asarray(bg, cdt)) * jnp.asarray(isq, cdt)
    df = (patches - jnp.asarray(fg, cdt)) * jnp.asarray(isq, cdt)
    terms = db * db - df * df
    adt = cdt if accum16 else jnp.float32
    # Same fixed-tree reduction order as the kernels and the core path.
    ll = pairwise_sum(terms.astype(adt)).astype(cdt)
    return ll, jnp.max(ll.astype(jnp.float32))
