"""Pallas kernel: stable scaled-square likelihood + fused max-finding.

Implements the paper's Eq. 4 form — ``((I-BG)*isq)^2 - ((I-FG)*isq)^2`` with
``isq = 1/sqrt(scale*N)`` hoisted to a precomputed scalar (the XU-pipeline
fix) — over a dense (particles, points) intensity matrix, and folds the
paper's *separate* max-finding kernel into the same pass via an SMEM running
max (grid is sequential per TPU core).

The gather that produces the (P, J) patch matrix stays in XLA
(`repro.core.likelihood.gather_patches`): per-element dynamic gathers inside
a TPU kernel serialize on the scalar core, whereas XLA lowers the batched
take to an efficient dynamic-gather HLO.  This is the deliberate hardware
adaptation of the paper's pixel-parallel CUDA kernel (one CUDA thread per
pixel → one VPU lane per pixel, one row per particle).

Padding: the J axis is padded to 128 lanes with the background/foreground
midpoint ((BG+FG)/2 = 164), for which the stable term is exactly zero, so
padding never perturbs the sum.

VMEM per step: block_p*128*itemsize(in) + block_p*4(out) ≈ 33 KiB for
block_p=128 in fp16.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import loglik_rows

__all__ = ["loglik_call", "LANES"]

LANES = 128


def _kernel(x_ref, out_ref, max_ref, m_s, *, bg, fg, isq, accum16):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        m_s[0, 0] = jnp.float32(-jnp.inf)

    x = x_ref[...]
    ll = loglik_rows(x, bg=bg, fg=fg, isq=isq, accum16=accum16)  # (block_p,)
    out_ref[...] = ll.astype(out_ref.dtype)[:, None]
    m_s[0, 0] = jnp.maximum(m_s[0, 0], jnp.max(ll.astype(jnp.float32)))

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit_max():
        max_ref[0, 0] = m_s[0, 0]


def loglik_call(
    patches2d: jax.Array,
    *,
    bg: float,
    fg: float,
    isq: float,
    block_p: int,
    accum16: bool,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """patches2d: (P, 128-padded J). Returns ((P, 1) loglik, (1,1) max fp32)."""
    p, jpad = patches2d.shape
    assert jpad % LANES == 0 and p % block_p == 0, (patches2d.shape, block_p)
    kernel = functools.partial(
        _kernel, bg=bg, fg=fg, isq=isq, accum16=accum16
    )
    return pl.pallas_call(
        kernel,
        grid=(p // block_p,),
        in_specs=[pl.BlockSpec((block_p, jpad), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_p, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, 1), patches2d.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(patches2d)
