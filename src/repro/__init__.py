"""repro: half-precision particle filtering on TPU, grown from arXiv:2308.00763.

Importing the package installs :mod:`repro.compat`'s jax version shims so
every entry point (library, tests, subprocess snippets) sees one API
surface regardless of the installed jax release.
"""

from repro import compat as _compat

_compat.install()
