"""Checkpoint/restore substrate."""

from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer,
    SlotSnapshotRing,
)
