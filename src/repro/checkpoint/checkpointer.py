"""Step-granular checkpointing with async writes and elastic restore.

Fault-tolerance contract (exercised in tests/test_checkpoint.py and
tests/test_elastic.py):

- **Atomicity**: a checkpoint is written to ``step_XXXX.tmp/`` and renamed
  into place only when every leaf + the manifest are on disk.  A crash
  mid-write never corrupts the latest valid checkpoint.
- **Async**: ``save(..., blocking=False)`` snapshots to host memory
  (device_get) and writes on a daemon thread — the training step is blocked
  only for the device->host copy, not the filesystem.
- **Restart-exactness**: the manifest stores the data-pipeline state (seed,
  step); counter-based batches (repro.data.tokens) make the resumed loss
  curve bitwise identical (tested).
- **Elastic restore**: leaves are saved *unsharded* (single-process
  container); ``restore(..., mesh=, shardings=)`` re-places them under any
  mesh — the restore path for "resume on a different topology".  On a real
  multi-host fleet each host would write its address-space shards
  (process-local leaves of a ``jax.Array``); the manifest format already
  records per-leaf shape/dtype so the layout generalizes.
- **Retention**: keep the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import collections
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer", "SlotSnapshotRing"]

_SENTINEL = "manifest.json"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class SlotSnapshotRing:
    """Host-side ring of per-slot serve-state snapshots for rollback.

    The serving scheduler's fault-tolerance ladder needs a clean recent
    copy of each slot's state to roll back to when a health sentinel
    trips (non-finite weights, poisoned caches).  Snapshots are the
    output of ``FilterBank.export_slot`` — particle rows, the
    log-weight row, and the step counter — pulled to host memory with
    the same device_get snapshot idiom :class:`Checkpointer.save` uses,
    so they are real copies: later donated in-place bank steps can
    never corrupt them retroactively.

    ``depth`` snapshots are kept per slot (newest first); a rollback
    consumes the newest (``pop``) so a snapshot that itself turns out
    poisoned is not restored twice — the ladder falls through to the
    next rung instead.  Snapshots index by the scheduler's *global* slot
    id, so one ring serves a whole multi-bank family.

    ``persist`` spills the newest snapshot of every slot through a
    :class:`Checkpointer` (atomic rename, manifest, bit-exact exotic
    dtypes) — the hook a multi-host fleet would use to survive process
    death, exercised in tests to keep the two layers compatible.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self._rings: dict[int, collections.deque] = {}
        self.pushes = 0
        self.rollbacks = 0

    def push(self, slot: int, particles_row: Any, log_w_row: Any,
             step: Any, n_active: Any = None, tick: int = 0) -> None:
        """Snapshot one slot (host copies; drops the oldest past depth)."""
        host = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), particles_row
        )
        ring = self._rings.setdefault(
            int(slot), collections.deque(maxlen=self.depth)
        )
        ring.append(
            {
                "particles": host,
                "log_w": np.asarray(jax.device_get(log_w_row)),
                "step": int(np.asarray(jax.device_get(step))),
                "n_active": (
                    None if n_active is None
                    else int(np.asarray(jax.device_get(n_active)))
                ),
                "tick": int(tick),
            }
        )
        self.pushes += 1

    def latest(self, slot: int) -> dict | None:
        ring = self._rings.get(int(slot))
        return ring[-1] if ring else None

    def pop(self, slot: int) -> dict | None:
        """Consume the newest snapshot (rollback): restoring the same
        snapshot twice after it failed to clear an incident would loop
        the ladder forever."""
        ring = self._rings.get(int(slot))
        if not ring:
            return None
        self.rollbacks += 1
        return ring.pop()

    def clear(self, slot: int) -> None:
        """Retire/re-admission: the ring holds a dead request's state."""
        self._rings.pop(int(slot), None)

    def move(self, src: int, dst: int) -> None:
        """A migration moved the request: its snapshots follow it (they
        re-import into any lane width via the masked cross-width draw)."""
        ring = self._rings.pop(int(src), None)
        self.clear(int(dst))
        if ring:
            self._rings[int(dst)] = ring

    def persist(self, checkpointer: "Checkpointer", step: int) -> None:
        """Write every slot's newest snapshot through ``checkpointer``
        (one atomic checkpoint holding the whole ring head)."""
        tree = {
            str(slot): ring[-1]["particles"]
            for slot, ring in self._rings.items()
            if ring
        }
        extra = {
            str(slot): {
                "step": ring[-1]["step"],
                "n_active": ring[-1]["n_active"],
                "tick": ring[-1]["tick"],
            }
            for slot, ring in self._rings.items()
            if ring
        }
        checkpointer.save(step, tree, extra=extra)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- write ----------------------------------------------------------

    def save(
        self, step: int, tree: Any, extra: dict | None = None,
        blocking: bool = True,
    ) -> None:
        """Snapshot now; write sync or on a background thread."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Any, extra: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat = _flatten(host)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, leaf in flat.items():
            fname = key.replace("/", "__") + ".npy"
            # numpy cannot round-trip ml_dtypes (bf16 loads back as V2):
            # persist exotic dtypes as same-width uint bit-views, exact.
            to_save, viewed = leaf, False
            if leaf.dtype.kind == "V" or str(leaf.dtype) not in (
                "float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint64", "uint32", "uint16", "uint8", "bool",
            ):
                to_save = leaf.view(f"u{leaf.dtype.itemsize}")
                viewed = True
            np.save(os.path.join(tmp, fname), to_save)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "bitview": viewed,
            }
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )

    # -- read -----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name, _SENTINEL)
                if os.path.exists(path):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        target: Any,
        shardings: Any = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``target`` (tree of arrays or
        ShapeDtypeStruct).  ``shardings``: optional matching pytree of
        NamedSharding for elastic re-placement on the current mesh."""
        base = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(base, _SENTINEL)) as f:
            manifest = json.load(f)

        flat_target = _flatten(target)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key, struct in flat_target.items():
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(base, meta["file"]))
            if meta.get("bitview"):
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
            assert tuple(arr.shape) == tuple(struct.shape), (
                key, arr.shape, struct.shape,
            )
            if key in flat_shard:
                loaded[key] = jax.device_put(arr, flat_shard[key])
            else:
                loaded[key] = jax.numpy.asarray(arr)
        # rebuild the original structure
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pa)
            for pa, _ in paths
        ]
        leaves = [loaded[k] for k in keys]
        return (
            jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["extra"],
        )
