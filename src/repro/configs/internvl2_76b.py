"""InternVL2-Llama3-76B [arXiv:2404.16821; unverified].

VLM: the LM backbone only (dense GQA, Llama-3-70B-like); the InternViT
frontend is a stub — ``input_specs()`` supplies precomputed patch
embeddings, projected by a learned connector.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    frontend="vlm",
    vlm_image_seq=256,
    tie_embeddings=False,
    rope_theta=5e5,
)
