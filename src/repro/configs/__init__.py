"""Architecture registry: --arch <id> -> ModelConfig."""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    reduced_config,
    register,
)

register("command-r-35b", "repro.configs.command_r_35b")
register("minitron-8b", "repro.configs.minitron_8b")
register("stablelm-12b", "repro.configs.stablelm_12b")
register("gemma3-27b", "repro.configs.gemma3_27b")
register("zamba2-2.7b", "repro.configs.zamba2_2p7b")
register("grok-1-314b", "repro.configs.grok_1_314b")
register("deepseek-moe-16b", "repro.configs.deepseek_moe_16b")
register("internvl2-76b", "repro.configs.internvl2_76b")
register("hubert-xlarge", "repro.configs.hubert_xlarge")
register("rwkv6-7b", "repro.configs.rwkv6_7b")
