"""xAI Grok-1 314B [hf:xai-org/grok-1; unverified].

MoE: 8 experts, top-2 routing, 32768 expert hidden width; GQA 48/8.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32768,
    tie_embeddings=True,
    # few wide experts: one-hot-matmul dispatch avoids the SPMD scatter
    # replication (9.1x step-bound win, EXPERIMENTS.md §Perf)
    moe_dispatch="einsum",
)
