"""DeepSeekMoE 16B [arXiv:2401.06066; hf].

Fine-grained MoE: 64 routed experts (width 1408) with top-6 routing plus
2 shared experts; MHA (16/16 heads).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    tie_embeddings=False,
    # one-hot-matmul dispatch: +53% compute-term but removes the SPMD
    # scatter replication — 2.45x step-bound win (EXPERIMENTS.md §Perf A4)
    moe_dispatch="einsum",
)
