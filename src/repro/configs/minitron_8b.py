"""Nvidia Minitron-8B (pruned Nemotron-4) [arXiv:2407.14679; hf].

Dense GQA decoder; 256k SentencePiece vocab.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    tie_embeddings=False,
    act="silu",
)
