"""HuBERT X-Large [arXiv:2106.07447; unverified].

Audio encoder-only transformer (w2v2 architecture); conv feature-extractor
frontend is a stub — ``input_specs()`` supplies 512-dim frame embeddings.
Masked-prediction head over 504 k-means targets.  No decode step.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    causal=False,
    frontend="frames",
    frame_dim=512,
    tie_embeddings=False,
    act="gelu",
)
