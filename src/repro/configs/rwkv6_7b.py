"""RWKV-6 "Finch" 7B [arXiv:2404.05892; hf].

Attention-free: data-dependent-decay linear attention (wkv6) + channel
mix; O(1) decode state.  64 heads of dim 64.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    is_rwkv=True,
    tie_embeddings=False,
)
