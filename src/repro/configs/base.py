"""Model/run configuration and the --arch registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ModelConfig", "register", "get_config", "list_archs", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (one instance per assigned arch)."""

    name: str
    family: str  # dense | moe | hybrid | ssm | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention pattern
    causal: bool = True
    window: int = 0          # sliding-window size for local layers (0 = full)
    global_every: int = 0    # every Nth layer is global (gemma3: 6); 0 = all global
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0        # routed-expert hidden width (deepseek fine-grained)
    capacity_factor: float = 1.25

    # SSM / hybrid (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0      # zamba2: shared attention block every N ssm layers

    # rwkv6
    is_rwkv: bool = False

    # structure
    is_encoder: bool = False
    frontend: str = "token"  # token | frames | vlm
    vlm_image_seq: int = 256  # leading patch-embedding positions for vlm
    frame_dim: int = 0        # audio frontend stub feature dim (0 -> d_model)
    use_bias: bool = False
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    act: str = "silu"

    # training-side knobs (overridable per run)
    remat: str = "full"      # full | dots | none
    scan_layers: bool = True
    # Metering: unroll every scan so compiled cost_analysis counts true trip
    # totals (XLA counts a scan body once — verified in this container).
    unroll_scans: bool = False
    # Perf knobs (§Perf iterations; current defaults = the winning settings,
    # see EXPERIMENTS.md §Perf for the baseline-vs-optimized history)
    decode_expand_kv: bool = False  # grouped decode KV (no head expansion)
    rwkv_chunk: int = 16            # wkv6 chunk length
    rwkv_intra_bf16: bool = False   # refuted: XLA already fuses the converts
    pin_decode_cache: bool = True   # pin KV cache sharding in decode
    moe_dispatch: str = "scatter"   # "scatter" | "einsum" (one-hot matmul)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def kv_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stack), for 6ND."""
        from repro.models.params import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k experts)."""
        from repro.models.params import count_params

        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Input-shape grid assigned to this paper (same 4 shapes for every arch).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, str] = {}


def register(arch_id: str, module: str) -> None:
    _REGISTRY[arch_id] = module


def list_archs() -> list[str]:
    _ensure_registered()
    return sorted(_REGISTRY)


def _ensure_registered() -> None:
    if not _REGISTRY:
        importlib.import_module("repro.configs")


def get_config(arch_id: str) -> ModelConfig:
    """Resolve --arch <id> to its ModelConfig."""
    _ensure_registered()
    arch_id = arch_id.replace("_", "-")
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {list_archs()}")
    mod = importlib.import_module(_REGISTRY[arch_id])
    return mod.CONFIG


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.attn_every else cfg.attn_every + 1),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=32 if cfg.ssm_state else 64,
        window=min(cfg.window, 64) if cfg.window else 0,
        global_every=cfg.global_every,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        vlm_image_seq=16 if cfg.frontend == "vlm" else cfg.vlm_image_seq,
        frame_dim=64 if cfg.frontend == "frames" else 0,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
