"""Zamba2-2.7B [arXiv:2411.15242; hf].

Hybrid: 54 Mamba2 (SSD, state 64) layers with a *shared* attention+MLP
block applied every 6 layers (one parameter set, 9 applications).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    tie_embeddings=True,
)
