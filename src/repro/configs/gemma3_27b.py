"""Gemma-3 27B [hf:google/gemma-3-27b-pt; unverified].

5:1 local:global attention (1024-token sliding window on local layers),
qk-norm, tied 262k vocab, 128k context target.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    window=1024,
    global_every=6,  # layers 6, 12, ... are global; rest are local
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)
