"""The paper's own workload: Rodinia-style object tracking particle filter.

Not an LM architecture — this config drives the tracking application and
the distributed-filter dry-run row (33.5M particles across 512 devices).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PFConfig:
    name: str = "rodinia-pf"
    num_particles: int = 65_536  # the paper's cap; dry-run scales to 2^25
    frame_height: int = 512
    frame_width: int = 512
    radius: int = 4
    num_frames: int = 100
    precision: str = "fp16"
    resampler: str = "systematic"
    backend: str = "pallas"


CONFIG = PFConfig()
