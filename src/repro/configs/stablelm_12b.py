"""StableLM-2 12B [hf:stabilityai/stablelm-2-12b; hf].

Dense GQA decoder with per-head qk-norm, 100352 (GPT-NeoX-ish) vocab.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
    qk_norm=True,
    tie_embeddings=False,
)
