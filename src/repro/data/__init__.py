"""Data substrates: the Rodinia-style synthetic video and LM token pipeline."""
