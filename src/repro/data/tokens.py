"""Deterministic synthetic batch pipeline (counter-based, restart-exact).

Batches are pure functions of (seed, step): threefry keys make the stream
bitwise reproducible across restarts and re-shards — the property the
fault-tolerance tests assert.  A Zipf-ish marginal over the vocab plus a
short-range Markov blend gives the loss something learnable so the 100M
example actually descends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["BatchSpec", "make_batch", "batch_structs"]


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    kind: str  # train | prefill | decode
    batch: int
    seq: int


def _tokens(key, shape, vocab: int) -> jax.Array:
    """Zipf-ish learnable token stream."""
    k1, k2, k3 = jax.random.split(key, 3)
    # zipf marginal via inverse-power transform of uniforms
    u = jax.random.uniform(k1, shape, jnp.float32, 1e-6, 1.0)
    z = jnp.floor(u ** (-1.0 / 1.1)) - 1.0
    base = jnp.clip(z, 0, vocab - 1).astype(jnp.int32)
    # short-range structure: with p=0.5 repeat previous token + 1 (mod V)
    rep = jax.random.bernoulli(k2, 0.5, shape)
    shifted = jnp.roll(base, 1, axis=-1)
    mixed = jnp.where(rep, (shifted + 1) % vocab, base)
    return mixed


def make_batch(cfg, spec: BatchSpec, seed: int, step) -> dict:
    """Materialize the batch for one step (host-side, then sharded)."""
    key = jax.random.fold_in(jax.random.key(seed), step)
    b, s = spec.batch, spec.seq
    if cfg.frontend == "frames":
        kf, kl, km = jax.random.split(key, 3)
        return {
            "frames": jax.random.normal(
                kf, (b, s, cfg.frame_dim), jnp.float32
            ),
            "labels": jax.random.randint(kl, (b, s), 0, cfg.vocab_size),
            "mask": jax.random.bernoulli(km, 0.3, (b, s)).astype(jnp.float32),
        }
    if cfg.frontend == "vlm":
        kt, kp = jax.random.split(key)
        s_txt = s - cfg.vlm_image_seq
        return {
            "tokens": _tokens(kt, (b, s_txt), cfg.vocab_size),
            "patch_embeds": jax.random.normal(
                kp, (b, cfg.vlm_image_seq, cfg.d_model), jnp.float32
            ),
        }
    return {"tokens": _tokens(key, (b, s), cfg.vocab_size)}


def batch_structs(cfg, spec: BatchSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    b, s = spec.batch, spec.seq
    if cfg.frontend == "frames":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frame_dim), jnp.float32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
    if cfg.frontend == "vlm":
        s_txt = s - cfg.vlm_image_seq
        return {
            "tokens": jax.ShapeDtypeStruct((b, s_txt), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (b, cfg.vlm_image_seq, cfg.d_model), jnp.float32
            ),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
