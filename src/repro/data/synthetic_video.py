"""Rodinia-style synthetic tracking video (paper section 5, "Verification").

A circular object of a single foreground intensity moves over a flat
background in a 2-D plane, advancing (+1 row, +2 cols) per frame — the means
of the paper's transition model (Eqs. 1-2) — bouncing specularly off the
frame walls; i.i.d. Gaussian pixel noise is added.  Defaults reproduce the
paper's setup: 100 frames, 512x512, disk radius matching the likelihood
template, background 100 / foreground 228.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["VideoConfig", "generate_video"]


@dataclasses.dataclass(frozen=True)
class VideoConfig:
    num_frames: int = 100
    height: int = 512
    width: int = 512
    radius: int = 4
    background: float = 100.0
    foreground: float = 228.0
    noise_std: float = 5.0
    # Transition-model means (paper Eq. 1-2): row velocity 1, col velocity 2.
    vel: tuple[float, float] = (1.0, 2.0)
    start: tuple[float, float] | None = None  # default: frame center


def _bounce(pos: jax.Array, lo: float, hi: jax.Array) -> jax.Array:
    """Specular reflection of a scalar coordinate into [lo, hi]."""
    span = hi - lo
    x = jnp.mod(pos - lo, 2.0 * span)
    x = jnp.where(x > span, 2.0 * span - x, x)
    return x + lo


def ground_truth(cfg: VideoConfig) -> jax.Array:
    """(T, 2) float32 object-center trajectory with specular bounces."""
    t = jnp.arange(cfg.num_frames, dtype=jnp.float32)
    start = cfg.start or (cfg.height / 2.0, cfg.width / 2.0)
    lo = float(cfg.radius)
    r = _bounce(start[0] + cfg.vel[0] * t, lo, cfg.height - 1.0 - cfg.radius)
    c = _bounce(start[1] + cfg.vel[1] * t, lo, cfg.width - 1.0 - cfg.radius)
    return jnp.stack([r, c], axis=-1)


def generate_video(
    key: jax.Array, cfg: VideoConfig = VideoConfig()
) -> tuple[jax.Array, jax.Array]:
    """Returns (video (T, H, W) float32 in [0, 255], truth (T, 2))."""
    truth = ground_truth(cfg)
    rows = jnp.arange(cfg.height, dtype=jnp.float32)
    cols = jnp.arange(cfg.width, dtype=jnp.float32)

    def frame(center, k):
        d2 = (rows[:, None] - center[0]) ** 2 + (cols[None, :] - center[1]) ** 2
        img = jnp.where(d2 <= cfg.radius**2, cfg.foreground, cfg.background)
        noise = cfg.noise_std * jax.random.normal(k, img.shape, jnp.float32)
        return jnp.clip(img + noise, 0.0, 255.0)

    keys = jax.random.split(key, cfg.num_frames)
    video = jax.vmap(frame)(truth, keys)
    return video, truth
