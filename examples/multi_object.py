"""Multi-object quickstart: one FilterBank slot per tracked target.

    PYTHONPATH=src python examples/multi_object.py [--targets 3] \
        [--precision bf16] [--particles 2048] [--backend pallas]

Composites N independently-moving objects into one synthetic video and
tracks them with a ``FilterBank`` (``repro.core.engine``): every target is
one bank slot sharing the same transition/likelihood model and the same
frame stream, with its own particle cloud seeded at its start position —
``make_multi_tracker_filter`` wires the per-slot starts through the spec's
``slot_init`` hook.  The whole bank steps as one jitted program: per-slot
weights, ESS, and resampling, batched kernels underneath.  Prints per-target
trajectories and RMSE, the bank-axis extension of ``quickstart.py``.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--targets", type=int, default=3)
    ap.add_argument("--precision", default="bf16",
                    choices=["fp64", "fp32", "bf16", "fp16", "bf16_mixed",
                             "fp16_mixed"])
    ap.add_argument("--particles", type=int, default=2048)
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--resampler", default="systematic",
                    choices=["systematic", "stratified", "multinomial",
                             "metropolis"])
    args = ap.parse_args()

    from repro.core import TrackerConfig, get_policy
    from repro.core.tracking import make_multi_tracker_filter
    from repro.data.synthetic_video import VideoConfig, generate_video

    policy = get_policy(args.precision)
    # N objects, each its own trajectory; composite by per-pixel max
    # (objects are brighter than background).
    rng = np.random.default_rng(0)
    margin = 16
    video, truths = None, []
    for i in range(args.targets):
        start = tuple(rng.uniform(margin, args.size - margin, 2))
        v, t = generate_video(
            jax.random.key(i),
            VideoConfig(num_frames=args.frames, height=args.size,
                        width=args.size, start=start),
        )
        video = v if video is None else jnp.maximum(video, v)
        truths.append(np.asarray(t))
    truth = np.stack(truths, axis=1)  # (T, N, 2)

    starts = jnp.asarray(truth[0], jnp.float32)
    cfg = TrackerConfig(
        num_particles=args.particles,
        height=args.size,
        width=args.size,
        backend=args.backend,
        resampler=args.resampler,
    )
    bank = make_multi_tracker_filter(cfg, policy, starts)
    t0 = time.perf_counter()
    final, outs = jax.jit(
        lambda k, v: bank.run(k, v, cfg.num_particles)
    )(jax.random.key(1), video)
    traj = outs.estimate["pos"]  # (T, N, 2)
    jax.block_until_ready(traj)
    dt = time.perf_counter() - t0

    est = np.asarray(traj, np.float64)
    err = np.sqrt(np.sum((est - truth) ** 2, -1))  # (T, N)
    print(f"targets={args.targets} precision={args.precision} "
          f"backend={args.backend} resampler={args.resampler} "
          f"particles/slot={args.particles}")
    print(f"{'frame':>5} " + " ".join(
        f"tgt{j}:(row,col,err)".rjust(22) for j in range(args.targets)))
    for i in range(0, args.frames, max(1, args.frames // 8)):
        cells = " ".join(
            f"({est[i, j, 0]:6.1f},{est[i, j, 1]:6.1f},{err[i, j]:5.1f})"
            for j in range(args.targets)
        )
        print(f"{i:5d} {cells}")
    rmse = np.sqrt((err**2).mean(0))
    print(f"\nper-target RMSE (px): {np.round(rmse, 2).tolist()}  "
          f"({dt / args.frames * 1e3:.1f} ms/frame incl. compile, "
          f"{args.targets} filters as one program)")
    if not np.isfinite(est).all():
        print("NOTE: non-finite estimates — check precision policy.")


if __name__ == "__main__":
    main()
