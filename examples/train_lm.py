"""End-to-end LM training driver: config -> data -> train loop -> checkpoints.

    PYTHONPATH=src python examples/train_lm.py --preset quick   (~10M, fast)
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Demonstrates the full substrate on CPU: deterministic data pipeline,
microbatched AdamW training with the bf16_mixed policy, async checkpointing
with auto-resume (kill it mid-run and rerun: it continues, bitwise).
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick", choices=["quick", "100m"])
    ap.add_argument("--arch", default="minitron-8b",
                    help="family donor for the reduced config")
    ap.add_argument("--steps", type=int, default=0, help="0 = preset default")
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    from repro.checkpoint import Checkpointer
    from repro.configs import get_config, reduced_config
    from repro.core.precision import get_policy
    from repro.data.tokens import BatchSpec, make_batch
    from repro.models import model as M
    from repro.optim import init_opt_state
    from repro.train import TrainConfig, make_train_step

    base = get_config(args.arch)
    if args.preset == "quick":
        cfg = reduced_config(base, num_layers=4, d_model=256, d_ff=1024,
                             vocab_size=2048, num_heads=8, num_kv_heads=4,
                             head_dim=32)
        spec = BatchSpec("train", 8, 128)
        steps = args.steps or 60
    else:  # ~100M params
        cfg = reduced_config(base, num_layers=12, d_model=768, d_ff=3072,
                             vocab_size=32_768, num_heads=12, num_kv_heads=4,
                             head_dim=64)
        spec = BatchSpec("train", 8, 512)
        steps = args.steps or 300
    policy = get_policy(args.precision)
    n_params = cfg.param_count()
    print(f"arch-family={args.arch} params={n_params/1e6:.1f}M "
          f"policy={policy.name} steps={steps}")

    tcfg = TrainConfig(microbatches=2, peak_lr=3e-4, warmup_steps=20,
                       total_steps=steps)
    ck = Checkpointer(args.ckpt_dir, keep=2)

    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    opt = init_opt_state(params, tcfg.opt)
    start = 0
    latest = ck.latest_step()
    if latest is not None:
        (restored, extra) = ck.restore(latest, {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        start = extra["next_step"]
        print(f"resumed from checkpoint step {latest} -> next_step {start}")

    step_fn = jax.jit(make_train_step(cfg, policy, tcfg))
    t0 = time.perf_counter()
    for i in range(start, steps):
        batch = make_batch(cfg, spec, 42, i)
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        if i % 10 == 0 or i == steps - 1:
            dt = (time.perf_counter() - t0) / max(i - start + 1, 1)
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({dt:.2f}s/step)")
        if (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt},
                    extra={"next_step": i + 1}, blocking=False)
    ck.wait()
    ck.save(steps, {"params": params, "opt": opt},
            extra={"next_step": steps})
    print("done; final checkpoint saved to", args.ckpt_dir)


if __name__ == "__main__":
    main()
