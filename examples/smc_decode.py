"""SMC decoding: the paper's particle filter steering LM token sampling.

    PYTHONPATH=src python examples/smc_decode.py [--particles 32]

The particle filter's propagate/weight/resample loop maps directly onto
autoregressive decoding:

    particle    = one partial sequence (its KV/recurrent cache = the state)
    propagate   = sample the next token from the model at temperature T
    weight      = log p(token) under the *reward* model (here: the same LM
                  at T=1, optionally with a constraint bonus)
    resample    = systematic resampling of sequences by weight (the paper's
                  scheme, in log space with the stable-LSE normalizer)

This is the serving-side integration of the paper's technique: batched
decode steps drive all particles at once, and resampling is a batch gather
of cache states.  A tiny randomly-initialized model keeps it CPU-friendly;
the mechanics are size-independent.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=1.3)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import resampling, stability
    from repro.core.precision import get_policy
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"), num_layers=2,
                         vocab_size=256)
    pol = get_policy(args.precision)
    n = args.particles
    params = M.init_params(jax.random.key(0), cfg, jnp.float32)
    cache = M.init_cache(cfg, n, args.steps + 1, pol.compute_dtype)

    tok = jnp.zeros((n,), jnp.int32)
    log_w = jnp.full((n,), -jnp.log(float(n)), jnp.float32)
    seqs = np.zeros((n, args.steps), np.int32)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )

    key = jax.random.key(42)
    total_resamples = 0
    for i in range(args.steps):
        logits, cache = decode(params, tok, jnp.int32(i), cache)
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)

        key, k_samp, k_res = jax.random.split(key, 3)
        # propagate: sample at high temperature (exploration)
        tok = jax.random.categorical(k_samp, logits / args.temperature, axis=-1)
        # weight: reward = model log-prob of the sampled token at T=1
        reward = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
        log_w = log_w + reward

        w, lse = stability.normalize_log_weights(log_w)
        ess = float(stability.effective_sample_size(w))
        seqs[:, i] = np.asarray(tok)
        if ess < args.ess_frac * n:
            anc = resampling.systematic(k_res, w, pol)
            # gather sequence state: tokens, caches, histories
            tok = jnp.take(tok, anc, axis=0)
            cache = jax.tree.map(
                lambda x: jnp.take(x, anc, axis=_batch_axis(x, n)), cache
            )
            seqs = seqs[np.asarray(anc)]
            log_w = jnp.full((n,), -jnp.log(float(n)), jnp.float32)
            total_resamples += 1
            marker = f"resampled (ess={ess:.1f})"
        else:
            marker = f"ess={ess:.1f}"
        if i % 4 == 0 or i == args.steps - 1:
            print(f"step {i:3d} mean_reward={float(reward.mean()):7.3f} "
                  f"{marker}")

    w, _ = stability.normalize_log_weights(log_w)
    best = int(jnp.argmax(w))
    mean_lp = float(jnp.sum(w * log_w))
    print(f"\n{total_resamples} resampling events over {args.steps} steps")
    print(f"best particle (w={float(w[best]):.3f}): "
          f"tokens={seqs[best].tolist()}")

    # baseline: independent sampling (no resampling) for comparison
    print("SMC mean weighted log-weight:", f"{mean_lp:.2f}")


def _batch_axis(x, n):
    """Locate the particle axis in a cache leaf (size-n dimension)."""
    for i, d in enumerate(x.shape):
        if d == n:
            return i
    raise ValueError(f"no particle axis in {x.shape}")


if __name__ == "__main__":
    main()
