"""SMC decoding: the paper's particle filter steering LM token sampling.

    PYTHONPATH=src python examples/smc_decode.py [--particles 32]

The particle filter's propagate/weight/resample loop maps directly onto
autoregressive decoding:

    particle    = one partial sequence (its KV/recurrent cache = the state)
    propagate   = sample the next token from the model at temperature T
    weight      = log p(token) under the *reward* model (here: the same LM
                  at T=1, optionally with a constraint bonus)
    resample    = systematic resampling of sequences by weight (the paper's
                  scheme, in log space with the stable-LSE normalizer)

Everything above is one ``SMCSpec`` handed to the ``ParticleFilter``
engine (``repro.core.engine``) — the same object that runs the paper's
object tracker runs this decode loop via ``stream()``; resampling is a
batch gather of cache states behind the spec's ``gather`` hook.  A tiny
randomly-initialized model keeps it CPU-friendly; the mechanics are
size-independent.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=1.3)
    ap.add_argument("--precision", default="bf16_mixed")
    ap.add_argument("--ess-frac", type=float, default=0.5)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.core import FilterConfig, ParticleFilter
    from repro.core.precision import get_policy
    from repro.launch.serve import make_smc_decode_spec
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"), num_layers=2,
                         vocab_size=256)
    pol = get_policy(args.precision)
    n = args.particles
    params = M.init_params(jax.random.key(0), cfg, jnp.float32)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )

    spec = make_smc_decode_spec(
        params, cfg, pol, decode,
        temperature=args.temperature, steps=args.steps,
    )
    flt = ParticleFilter(
        spec, FilterConfig(policy=pol, ess_threshold=args.ess_frac)
    )

    total_resamples = 0
    state = None
    for i, (state, out) in enumerate(
        flt.stream(jax.random.key(42), range(args.steps), n)
    ):
        resampled = bool(out.resampled)
        total_resamples += resampled
        if i % 4 == 0 or i == args.steps - 1:
            marker = (
                f"resampled (ess={float(out.ess):.1f})"
                if resampled
                else f"ess={float(out.ess):.1f}"
            )
            print(f"step {i:3d} mean_reward={float(out.estimate['reward']):7.3f} "
                  f"{marker}")

    from repro.core import stability

    seqs = np.asarray(state.particles["seq"])
    log_w = state.log_weights.astype(jnp.float32)
    w, _ = stability.normalize_log_weights(log_w)
    best = int(jnp.argmax(w))
    # The engine renormalizes weights every step, so sequence quality is
    # read off the lineage log-prob the spec accumulates in the particles.
    cum = state.particles["cum_reward"].astype(jnp.float32)
    mean_lp = float(jnp.sum(w * cum))
    print(f"\n{total_resamples} resampling events over {args.steps} steps")
    print(f"best particle (w={float(w[best]):.3f}): "
          f"tokens={seqs[best].tolist()}")

    print("SMC mean weighted cumulative log-prob:", f"{mean_lp:.2f}")


if __name__ == "__main__":
    main()
