"""Quickstart: track a bouncing object with the half-precision filter.

    PYTHONPATH=src python examples/quickstart.py [--precision bf16] \
        [--particles 4096] [--backend pallas]

Generates the Rodinia-style synthetic video and runs the tracker through
the ``ParticleFilter`` engine (``repro.core.engine``): the precision
policy, kernel backend, resampler, and ESS threshold are all fields of one
``FilterConfig``, so switching ``--precision fp16`` or ``--backend
pallas`` changes a registry name, never the call site.  Prints per-frame
estimates + accuracy, mirroring the paper's verification experiment
(Fig. 4).
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--precision", default="bf16",
                    choices=["fp64", "fp32", "bf16", "fp16", "bf16_mixed",
                             "fp16_mixed", "fp16_naive"])
    ap.add_argument("--particles", type=int, default=4096)
    ap.add_argument("--frames", type=int, default=60)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    from repro.core import TrackerConfig, get_policy, make_tracker_filter
    from repro.data.synthetic_video import VideoConfig, generate_video

    policy = get_policy(args.precision)
    video, truth = generate_video(
        jax.random.key(0),
        VideoConfig(num_frames=args.frames, height=args.size, width=args.size),
    )
    cfg = TrackerConfig(
        num_particles=args.particles,
        height=args.size,
        width=args.size,
        backend=args.backend,
    )
    flt = make_tracker_filter(cfg, policy)
    t0 = time.perf_counter()
    final, outs = jax.jit(
        lambda k, v: flt.run(k, v, cfg.num_particles)
    )(jax.random.key(1), video)
    traj = outs.estimate["pos"]
    jax.block_until_ready(traj)
    dt = time.perf_counter() - t0

    t = np.asarray(traj, np.float64)
    g = np.asarray(truth, np.float64)
    err = np.sqrt(np.sum((t - g) ** 2, -1))
    print(f"precision={args.precision} backend={args.backend} "
          f"particles={args.particles}")
    print(f"{'frame':>5} {'est_row':>8} {'est_col':>8} {'true_row':>8} "
          f"{'true_col':>8} {'err_px':>7} {'ess':>7}")
    ess = np.asarray(outs.ess, np.float64)
    for i in range(0, args.frames, max(1, args.frames // 12)):
        print(f"{i:5d} {t[i,0]:8.2f} {t[i,1]:8.2f} {g[i,0]:8.2f} "
              f"{g[i,1]:8.2f} {err[i]:7.2f} {ess[i]:7.1f}")
    print(f"\nRMSE: {np.sqrt((err ** 2).mean()):.3f} px over {args.frames} "
          f"frames  ({dt / args.frames * 1e3:.1f} ms/frame incl. compile)")
    if not np.isfinite(t).all():
        print("NOTE: non-finite estimates — this is the paper's naive-fp16 "
              "failure mode (expected for --precision fp16_naive).")


if __name__ == "__main__":
    main()
