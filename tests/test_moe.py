"""MoE routing/dispatch properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import moe
from repro.models.layers import mlp
from repro.models.params import init_params


def _cfg(**kw):
    base = reduced_config(get_config("deepseek-moe-16b"))
    return dataclasses.replace(base, **kw)


def test_single_expert_topk1_equals_dense_mlp():
    """E=1, k=1, no shared: the MoE layer must equal a plain gated MLP with
    the same weights (gate prob is 1 after renormalization)."""
    cfg = _cfg(num_experts=1, experts_per_token=1, num_shared_experts=0,
               capacity_factor=8.0)
    spec = moe.moe_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe.moe(params, x, cfg)
    dense_params = {
        "wi_gate": params["routed"]["wi_gate"][0],
        "wi_up": params["routed"]["wi_up"][0],
        "wo": params["routed"]["wo"][0],
    }
    want = mlp(dense_params, x, cfg.act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_grad_flows_through_dispatch():
    cfg = _cfg(capacity_factor=4.0)
    spec = moe.moe_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = moe.moe(p, x, cfg)
        return jnp.sum(out * out) + 0.01 * aux

    g = jax.grad(loss)(params)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
    # router receives gradient (through gate weighting + aux loss)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0


def test_capacity_drops_lose_tokens_but_stay_finite():
    """With capacity_factor near 0, most tokens drop — outputs must be
    finite and bounded, not NaN."""
    cfg = _cfg(capacity_factor=0.01)
    spec = moe.moe_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model), jnp.float32)
    out, aux = moe.moe(params, x, cfg)
    assert bool(jnp.isfinite(out).all())


def test_aux_loss_balanced_vs_collapsed():
    """The Switch aux loss must be ~1 for uniform routing and >1 for a
    collapsed router."""
    cfg = _cfg(num_shared_experts=0)
    e = cfg.num_experts
    spec = moe.moe_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    # collapsed router: expert 0 scores sum(|x|) > 0, the rest score 0 —
    # with positive inputs every token picks expert 0 first
    collapsed = dict(params)
    router = np.zeros(params["router"].shape, np.float32)
    router[:, 0] = 1.0
    collapsed["router"] = jnp.asarray(router)
    x = jnp.abs(
        jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    )
    _, aux_rand = moe.moe(params, x, cfg)
    _, aux_coll = moe.moe(collapsed, x, cfg)
    assert float(aux_coll) > float(aux_rand) > 0.5


def test_decode_shape_single_token():
    cfg = _cfg()
    spec = moe.moe_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 1, cfg.d_model), jnp.float32)
    out, _ = moe.moe(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
