"""Logical-axis sharding rules + HLO collective parser."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.hlo import collective_stats, op_mix
from repro.models.params import (
    SERVE_RULES,
    TRAIN_RULES,
    ParamSpec,
    gather_for_compute,
    logical_to_spec,
)


def _mesh2d():
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def test_divisibility_fallback():
    mesh = jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
    # 8 kv heads on a 16-way axis would replicate; with size-1 axes anything
    # "divides" — exercise the non-divisible path with a fake big mesh via
    # direct call against axis sizes
    spec = logical_to_spec(mesh, (10, 3), ("embed", "heads"), TRAIN_RULES)
    assert isinstance(spec, P)


def test_no_axis_reuse_across_dims():
    """Two dims mapping to the same mesh axis: second one replicates."""
    mesh = _mesh2d()
    spec = logical_to_spec(
        mesh, (8, 8), ("embed", "embed"), TRAIN_RULES
    )  # both want "data"
    used = [s for s in spec if s is not None]
    assert len(used) <= 1


def test_gather_for_compute_noop_without_mesh():
    p = {"w": jnp.ones((4, 4))}
    s = {"w": ParamSpec((4, 4), ("embed", "mlp"))}
    out = gather_for_compute(p, s)
    assert out["w"] is p["w"]


def test_serve_rules_differ():
    assert TRAIN_RULES["embed"] == "data"
    assert SERVE_RULES["embed"] is None
    # context parallelism: cache seq takes whatever the batch leaves free
    assert SERVE_RULES["cache_seq"] == ("model", "data")


def test_tuple_axis_subset_fallback():
    """cache_seq -> ("model","data") keeps "model" when batch took "data"."""
    mesh = _mesh2d()  # (n, 1) so "model" is size 1 — exercise shape logic
    spec = logical_to_spec(
        mesh, (8, 64, 2, 4), ("batch", "cache_seq", "kv_heads", "head_dim"),
        SERVE_RULES,
    )
    # batch gets ("data",) (divisible), cache_seq can only use leftover axes
    flat = [s for s in spec if s is not None]
    assert len(set(str(f) for f in flat)) == len(flat)  # no axis reused


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = bf16[256,1024]{1,0} parameter(0)
  %ag = bf16[256,16384]{1,0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %ar = f32[512]{0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%sum
  %cp = bf16[64,64]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %conv1 = f32[8]{0} convert(%z)
  %e = f32[8]{0} exponential(%conv1)
}
"""


def test_collective_parser():
    stats = collective_stats(HLO_SAMPLE, 512)
    wb = stats["wire_bytes"]
    # all-gather: 256*16384*2 bytes * 15/16
    assert abs(wb["all-gather"] - 256 * 16384 * 2 * 15 / 16) < 1
    # all-reduce: 512*4 * 2*15/16 (group size 16 from iota form)
    assert abs(wb["all-reduce"] - 512 * 4 * 2 * 15 / 16) < 1
    assert wb["collective-permute"] == 64 * 64 * 2
    assert stats["counts"]["all-gather"] == 1


def test_op_mix_counts():
    mix = op_mix(HLO_SAMPLE)
    assert mix["convert"] == 1
    assert mix["exponential"] == 1
