import os
import sys

# src-layout import without installation (PYTHONPATH=src also works)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
