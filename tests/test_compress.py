"""Int8 gradient compression: quantization error bounds + error feedback +
distributed all-reduce equivalence (subprocess, 8 devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import compress
from tests._mp import run_with_devices


@given(st.integers(1, 2000), st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale):
    x = (
        jax.random.normal(jax.random.key(n), (n,), jnp.float32) * scale
    )
    q, s = compress.quantize_blockwise(x)
    y = compress.dequantize_blockwise(q, s, x.shape)
    err = np.abs(np.asarray(x) - np.asarray(y))
    # per-block max error <= scale/254 * blockmax... conservative: amax/127
    blocks = np.asarray(jnp.pad(x, (0, (-n) % 256)).reshape(-1, 256))
    bound = np.repeat(np.abs(blocks).max(1) / 127.0 * 0.5 + 1e-7, 256)[:n]
    assert (err <= bound + 1e-6).all()


def test_error_feedback_removes_bias():
    """With error feedback, the *accumulated* compressed sum converges to
    the accumulated true sum (bias does not build up)."""
    x = jnp.full((256,), 0.003, jnp.float32)  # quantizes badly alone
    err = jnp.zeros_like(x)
    acc = np.zeros(256, np.float64)
    for _ in range(50):
        target = x + err
        q, s = compress.quantize_blockwise(target)
        local = compress.dequantize_blockwise(q, s, x.shape)
        err = target - local
        acc += np.asarray(local, np.float64)
    true = 50 * 0.003
    np.testing.assert_allclose(acc, true, rtol=0.02)


def test_compressed_psum_matches_exact_on_8_devices():
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compress

mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))

def body(g, e):
    out, e2 = compress.compressed_psum({"w": g[0]}, "d", {"w": e[0]})
    exact = jax.lax.psum(g[0], "d")
    return out["w"], exact, e2["w"][None]

g = jax.random.normal(jax.random.key(0), (8, 1024), jnp.float32)
e = jnp.zeros((8, 1024), jnp.float32)
f = jax.shard_map(body, mesh=mesh, in_specs=(P("d"), P("d")),
                  out_specs=(P(), P(), P("d")), check_vma=False)
comp, exact, _ = f(g, e)
rel = float(jnp.max(jnp.abs(comp - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
assert rel < 0.02, rel
print("OK rel", rel)
""",
        devices=8,
    )
    assert "OK" in out
