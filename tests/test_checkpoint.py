"""Fault tolerance: atomic checkpoints, async writes, restart-exactness."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from repro.core.precision import get_policy
from repro.data.tokens import BatchSpec, make_batch
from repro.models import model as M
from repro.optim import init_opt_state
from repro.train import TrainConfig, make_train_step


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(3, tree, extra={"data_step": 3})
    assert ck.latest_step() == 3
    restored, extra = ck.restore(3, tree)
    assert extra == {"data_step": 3}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_save_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(), blocking=False)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_interrupted_write_ignored(tmp_path):
    """A stale .tmp directory (crash mid-write) is not a valid checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ck.latest_step() == 1


def test_restart_exact_training(tmp_path):
    """Train 6 steps; or train 3, checkpoint, restart, train 3 more —
    identical parameters (counter-based data + pure step fn)."""
    cfg = reduced_config(get_config("minitron-8b"))
    pol = get_policy("bf16_mixed")
    tcfg = TrainConfig(microbatches=1, total_steps=10, warmup_steps=1)
    spec = BatchSpec("train", 4, 32)
    step_fn = jax.jit(make_train_step(cfg, pol, tcfg))

    def fresh():
        p = M.init_params(jax.random.key(1), cfg, jnp.float32)
        return p, init_opt_state(p, tcfg.opt)

    # continuous run
    p, o = fresh()
    for i in range(6):
        p, o, _ = step_fn(p, o, make_batch(cfg, spec, 42, i), jnp.int32(i))
    ref = jax.device_get(p)

    # interrupted run
    p, o = fresh()
    for i in range(3):
        p, o, _ = step_fn(p, o, make_batch(cfg, spec, 42, i), jnp.int32(i))
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"params": p, "opt": o}, extra={"next_step": 3})
    del p, o
    restored, extra = ck.restore(3, {"params": ref, "opt": init_opt_state(ref, tcfg.opt)})
    p, o = restored["params"], restored["opt"]
    for i in range(extra["next_step"], 6):
        p, o, _ = step_fn(p, o, make_batch(cfg, spec, 42, i), jnp.int32(i))

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(jax.device_get(p))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_across_meshes():
    """Save on an 8-device mesh, restore on a 2-device mesh — bitwise."""
    from tests._mp import run_with_devices

    snippet = """
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import PartitionSpec as P
from repro.checkpoint import Checkpointer

n = {n}
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
sh = {{"w": jax.NamedSharding(mesh, P("data"))}}
tree = jax.device_put(tree, sh)
ck = Checkpointer("{d}")
if {save}:
    ck.save(1, tree)
    print("saved")
else:
    restored, _ = ck.restore(1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
    assert len(restored["w"].sharding.device_set) == n
    print("restored OK")
"""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        run_with_devices(snippet.format(n=8, d=d, save=True), devices=8)
        out = run_with_devices(snippet.format(n=2, d=d, save=False), devices=2)
        assert "restored OK" in out
