"""The compat shim layer's version gate: fallbacks install only where the
running jax lacks the native symbol, and the set of live shims can only
shrink as jax upgrades."""

import jax

from repro import compat

# Every shim the jax 0.4.x line needs — the frozen high-water mark.  A
# jax upgrade may only *remove* entries from the active set (the symbol
# went native); a new entry appearing here means a new shim was added to
# compat.py and must be registered consciously, with its import-time
# native probe.
FULL_04X_SHIM_SET = frozenset(
    {
        "enable_x64",
        "set_mesh",
        "get_abstract_mesh",
        "shard_map",
        "make_mesh_axis_types",
        "AxisType",
        "axis_size",
    }
)


def test_shim_set_only_shrinks():
    """active_shims() never exceeds the known 0.4.x full set."""
    active = compat.active_shims()
    assert active <= FULL_04X_SHIM_SET, (
        f"unregistered shims {sorted(active - FULL_04X_SHIM_SET)}: new "
        "compat fallbacks must be added to FULL_04X_SHIM_SET explicitly"
    )


def test_registry_covers_every_probe():
    """Every probed symbol has a recorded native/fallback verdict."""
    assert set(compat._NATIVE) == FULL_04X_SHIM_SET


def test_04x_line_needs_every_shim():
    """On the 0.4.x line (this container) the full set is active; on a
    newer jax the assertion flips to requiring the set to have shrunk —
    the regression this file exists for."""
    if jax.__version__.startswith("0.4."):
        assert compat.active_shims() == FULL_04X_SHIM_SET
    else:
        assert compat.active_shims() < FULL_04X_SHIM_SET


def test_native_make_mesh_not_wrapped_when_axis_types_supported():
    """The make_mesh wrapper exists iff the native one lacks axis_types
    (the one shim that *replaces* a native symbol instead of filling a
    hole — the sharpest place for the version gate to regress)."""
    is_native = compat.make_mesh is compat._native_make_mesh
    assert is_native == ("make_mesh_axis_types" not in compat.active_shims())


def test_install_is_idempotent():
    """A second install() neither re-patches nor clobbers anything."""
    before = {
        "enable_x64": jax.enable_x64,
        "set_mesh": jax.set_mesh,
        "shard_map": jax.shard_map,
        "make_mesh": jax.make_mesh,
        "axis_size": jax.lax.axis_size,
        "AxisType": jax.sharding.AxisType,
        "get_abstract_mesh": jax.sharding.get_abstract_mesh,
    }
    compat.install()
    for name, obj in before.items():
        mod = {
            "axis_size": jax.lax,
            "AxisType": jax.sharding,
            "get_abstract_mesh": jax.sharding,
        }.get(name, jax)
        assert getattr(mod, name) is obj, f"install() moved jax.{name}"


def test_patched_jax_surface_matches_compat():
    """Post-install, the jax attributes the codebase uses resolve to the
    same objects compat exports — native or fallback alike."""
    assert jax.enable_x64 is compat.enable_x64
    assert jax.set_mesh is compat.set_mesh
    assert jax.shard_map is compat.shard_map
    assert jax.make_mesh is compat.make_mesh
    assert jax.lax.axis_size is compat.axis_size
    assert jax.sharding.AxisType is compat.AxisType
    assert jax.sharding.get_abstract_mesh is compat.get_abstract_mesh
